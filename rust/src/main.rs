//! `streamrec` — leader entrypoint and CLI.
//!
//! Subcommands:
//! * `run`        — drive one stream through a live cluster session and
//!   print the live metrics + final report.
//! * `experiment` — run a declarative drift-scenario grid from a TOML
//!   file (baseline vs distributed, windowed recall, `BENCH_drift.json`).
//! * `worker`     — host workers behind TCP for a remote coordinator
//!   (the `[cluster] workers = ["tcp://..."]` peer).
//! * `table1`     — print dataset characteristics.
//! * `gen-data`   — write a synthetic rating stream to CSV.
//! * `backends`   — cross-check native vs PJRT backends on one stream.
//!
//! Examples:
//! ```text
//! streamrec run --dataset ml-like:100000 --ni 4 --algorithm isgd
//! streamrec run --dataset nf-like:50000 --ni 2 --forgetting lru
//! streamrec experiment --config configs/drift_smoke.toml
//! streamrec worker --listen 127.0.0.1:7461
//! streamrec backends --events 3000
//! ```

use anyhow::{bail, Result};

use streamrec::config::{Algorithm, Backend, Forgetting, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::stats::DatasetStats;
use streamrec::data::DatasetSpec;
use streamrec::experiments::{run_scenario, Scenario};
use streamrec::util::args::Args;
use streamrec::util::logging;

fn main() -> Result<()> {
    logging::init();
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("worker") => cmd_worker(&args),
        Some("table1") => cmd_table1(&args),
        Some("gen-data") => cmd_gen_data(&args),
        Some("backends") => cmd_backends(&args),
        Some(other) => bail!("unknown subcommand '{other}'; see --help"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "streamrec — distributed real-time recommender for big data streams

USAGE:
  streamrec run [--config FILE] [--dataset SPEC] [--algorithm isgd|cosine]
                [--ni N] [--w W] [--backend native|pjrt]
                [--forgetting none|lru|lfu|decay] [--seed S] [--top-n N]
  streamrec experiment --config SCENARIO.toml
                                    # drift-scenario grid: baseline vs
                                    # distributed, windowed recall curves,
                                    # BENCH_drift.json (docs/EXPERIMENTS.md)
  streamrec worker --listen HOST:PORT [--once]
                                    # host workers for a remote coordinator
                                    # ([cluster] workers = [\"tcp://...\"]);
                                    # --once exits after the peer finishes
  streamrec table1 [--events N] [--seed S]
  streamrec gen-data --dataset SPEC --out FILE.csv
  streamrec backends [--events N]   # native-vs-PJRT cross-check

DATASET SPEC:
  ml-like:<events>   synthetic MovieLens-25M-shaped stream
  nf-like:<events>   synthetic Netflix-shaped stream
  ml-csv:<path>      real MovieLens ratings.csv
  nf-file:<path>     real Netflix combined_data file

Paper figures/tables: `cargo run --release --bin figures -- --exp all`."
    );
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    if let Some(a) = args.get("algorithm") {
        cfg.algorithm = Algorithm::parse(a)?;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b)?;
    }
    let n_i = args.get_parse::<u64>("ni")?.unwrap_or(cfg.topology.n_i);
    let w = args.get_parse::<u64>("w")?.unwrap_or(cfg.topology.w);
    cfg.topology = Topology::new(n_i, w)?;
    if let Some(f) = args.get("forgetting") {
        cfg.forgetting = match f {
            "none" => Forgetting::None,
            "lru" => Forgetting::Lru {
                trigger_secs: args
                    .get_parse("lru-trigger-secs")?
                    .unwrap_or(86_400),
                max_idle_secs: args
                    .get_parse("lru-max-idle-secs")?
                    .unwrap_or(5 * 86_400),
            },
            "lfu" => Forgetting::Lfu {
                trigger_events: args
                    .get_parse("lfu-trigger-events")?
                    .unwrap_or(10_000),
                min_freq: args.get_parse("lfu-min-freq")?.unwrap_or(2),
            },
            "decay" => Forgetting::Decay {
                trigger_events: args
                    .get_parse("decay-trigger-events")?
                    .unwrap_or(10_000),
                factor: args.get_parse("decay-factor")?.unwrap_or(0.9),
            },
            other => bail!("unknown forgetting '{other}'"),
        };
    }
    if let Some(n) = args.get_parse("top-n")? {
        cfg.top_n = n;
    }
    if args.flag("cosine-strict") {
        cfg.cosine_strict = true;
    }
    if let Some(s) = args.get_parse("seed")? {
        cfg.seed = s;
    }
    if let Some(d) = args.get("artifacts-dir") {
        cfg.artifacts_dir = d.to_string();
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let spec = DatasetSpec::parse(
        &args.get_or("dataset", "ml-like:100000"),
        cfg.seed,
    )?;
    let events = spec.load()?;
    let label = format!(
        "{}-{}-ni{}-{}",
        cfg.algorithm.name(),
        spec.name(),
        cfg.topology.n_i,
        cfg.forgetting.name()
    );
    // Drive the stream through a live session (the `run_pipeline`
    // wrapper would hide the live-metrics surface this command prints).
    let mut cluster = Cluster::spawn_labeled(&cfg, &label)?;
    cluster.ingest_batch(&events)?;
    let live = cluster.metrics()?;
    println!(
        "live: ingested={} processed={} buffered={} recall={:.4} \
         queries={} shed={} cache_hits={} rescales={} recoveries={} \
         replayed={} checkpoint_bytes={} router_epoch={}",
        live.ingested,
        live.processed,
        live.buffered,
        live.recall,
        live.queries,
        live.shed_queries,
        live.cache_hits,
        live.rescales,
        live.recoveries,
        live.replayed_events,
        live.checkpoint_bytes,
        live.router_epoch
    );
    let report = cluster.finish()?;
    println!("{}", report.summary());
    println!(
        "latency: {}   route: {:.0} ns/event   backpressure: {:.1} ms   \
         recv wait: {:.1} ms   send batch(mean): {:.1}",
        report.latency().summary(),
        report.route_ns_per_event,
        report.backpressure_ns as f64 / 1e6,
        report.recv_blocked_ns as f64 / 1e6,
        report.mean_send_batch
    );
    for w in &report.workers {
        println!(
            "  worker {:>3}: processed={:>8} hits={:>7} users={:>7} \
             items={:>6} aux={:>8} sweeps={} evicted={}",
            w.worker_id,
            w.processed,
            w.hits,
            w.state.users,
            w.state.items,
            w.state.aux,
            w.sweeps,
            w.evicted
        );
    }
    if let Some(out) = args.get("out") {
        let mut w = streamrec::util::csv::CsvWriter::create(
            out,
            &["seq", "recall_ma"],
        )?;
        for (seq, r) in &report.recall_curve {
            w.row(&[seq.to_string(), format!("{r:.6}")])?;
        }
        w.flush()?;
        println!("recall curve written to {out}");
    }
    Ok(())
}

/// Run a declarative drift-scenario grid (`--config scenario.toml`):
/// baseline `n_i = 1` vs distributed topologies over drifted streams,
/// per-window recall CSVs, and a `BENCH_drift.json` summary. The
/// scenario schema is documented in docs/EXPERIMENTS.md.
fn cmd_experiment(args: &Args) -> Result<()> {
    let path = args
        .get("config")
        .ok_or_else(|| anyhow::anyhow!(
            "experiment needs --config <scenario.toml> (see docs/EXPERIMENTS.md)"
        ))?;
    let scenario = Scenario::from_file(path)?;
    let t0 = std::time::Instant::now();
    let outcome = run_scenario(&scenario)?;
    println!(
        "== scenario '{}': {} runs, drift={} ==",
        scenario.name,
        outcome.runs.len(),
        scenario.drift.kind.map(|k| k.name()).unwrap_or("none"),
    );
    for run in &outcome.runs {
        let drift_cols = match run.response {
            Some(r) => format!(
                "pre={:.4} dip={:.4} recovered={:.4}",
                r.pre, r.dip, r.recovered
            ),
            None => "-".to_string(),
        };
        println!(
            "  {:42} recall={:.4} thpt={:>9.0} ev/s rescales={} \
             recoveries={}  {}",
            run.label,
            run.report.avg_recall,
            run.report.throughput,
            run.report.rescales,
            run.report.recoveries,
            drift_cols
        );
    }
    println!(
        "done in {:.1}s; windows under {}/, summary in {}",
        t0.elapsed().as_secs_f64(),
        outcome.out_dir.display(),
        outcome.bench_path.display()
    );
    Ok(())
}

/// Host `WorkerActor`s behind TCP for a remote coordinator. Runs until
/// killed; with `--once`, exits after the server has served at least one
/// connection and then sat idle for two seconds (CI smoke / scripted
/// runs).
fn cmd_worker(args: &Args) -> Result<()> {
    use std::io::Write as _;
    let listen = args.get_or("listen", "127.0.0.1:7461");
    let server = streamrec::net::WorkerServer::bind(&listen)?;
    // Flush: stdout is block-buffered when piped, and scripts wait for
    // this line before dialing.
    println!("streamrec worker listening on {}", server.local_addr());
    std::io::stdout().flush().ok();
    if args.flag("once") {
        server.wait_idle(std::time::Duration::from_secs(2));
        let served = server.connections();
        let routed = server.events_routed();
        server.shutdown()?;
        println!(
            "streamrec worker: served {served} connections, \
             routed {routed} events"
        );
        return Ok(());
    }
    loop {
        std::thread::park();
    }
}

fn cmd_table1(args: &Args) -> Result<()> {
    let events: u64 = args.get_parse("events")?.unwrap_or(120_000);
    let seed: u64 = args.get_parse("seed")?.unwrap_or(42);
    for name in ["ml-like", "nf-like"] {
        let spec = DatasetSpec::parse(&format!("{name}:{events}"), seed)?;
        let data = spec.load()?;
        println!("{}", DatasetStats::compute(name, &data).table_row());
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let spec = DatasetSpec::parse(
        &args.get_or("dataset", "ml-like:100000"),
        args.get_parse("seed")?.unwrap_or(42),
    )?;
    let out = args.get_or("out", "synthetic.csv");
    let events = spec.load()?;
    let mut w = streamrec::util::csv::CsvWriter::create(
        &out,
        &["userId", "movieId", "rating", "timestamp"],
    )?;
    for e in &events {
        w.row(&[
            e.user.to_string(),
            e.item.to_string(),
            format!("{:.1}", e.rating),
            e.ts.to_string(),
        ])?;
    }
    w.flush()?;
    println!("wrote {} events to {out}", events.len());
    Ok(())
}

/// Cross-check: run the same stream through the native and PJRT backends
/// and compare recall trajectories + state. The models are seeded
/// identically, so any divergence beyond f32 noise is a bug.
fn cmd_backends(args: &Args) -> Result<()> {
    let n: u64 = args.get_parse("events")?.unwrap_or(3000);
    let spec = DatasetSpec::parse(&format!("nf-like:{n}"), 7)?;
    let events = spec.load()?;
    let mut results = Vec::new();
    for backend in [Backend::Native, Backend::Pjrt] {
        let cfg = RunConfig {
            backend,
            artifacts_dir: args.get_or("artifacts-dir", "artifacts"),
            ..RunConfig::default()
        };
        let label = format!("backend-{}", backend.name());
        let mut cluster = Cluster::spawn_labeled(&cfg, &label)?;
        cluster.ingest_batch(&events)?;
        let report = cluster.finish()?;
        println!("{}", report.summary());
        results.push(report);
    }
    let (a, b) = (&results[0], &results[1]);
    println!(
        "hits: native={} pjrt={} (delta {})",
        a.hits,
        b.hits,
        (a.hits as i64 - b.hits as i64).abs()
    );
    let tol = (a.events / 100).max(10);
    if (a.hits as i64 - b.hits as i64).unsigned_abs() > tol {
        bail!("backends diverged beyond tolerance");
    }
    println!("backends agree within tolerance ({tol} hits)");
    Ok(())
}
