//! Dataset substrate: event types, synthetic stream generators shaped
//! after Table 1, real-dataset loaders, and dataset statistics.

pub mod drift;
pub mod movielens;
pub mod stats;
pub mod synth;
pub mod types;

use anyhow::Result;

use drift::{DriftConfig, DriftStream};
use synth::{SyntheticConfig, SyntheticStream};
use types::Rating;

/// Which dataset a run consumes.
#[derive(Debug, Clone)]
pub enum DatasetSpec {
    /// Synthetic MovieLens-25M-shaped stream.
    MovielensLike {
        /// Events to generate.
        events: u64,
        /// Generator seed.
        seed: u64,
    },
    /// Synthetic Netflix-shaped stream.
    NetflixLike {
        /// Events to generate.
        events: u64,
        /// Generator seed.
        seed: u64,
    },
    /// Real MovieLens ratings.csv.
    MovielensCsv {
        /// Path to `ratings.csv`.
        path: String,
        /// Optional cap on loaded events.
        limit: Option<u64>,
    },
    /// Real Netflix combined_data file.
    NetflixFile {
        /// Path to a `combined_data_N.txt` file.
        path: String,
        /// Optional cap on loaded events.
        limit: Option<u64>,
    },
}

impl DatasetSpec {
    /// Parse `ml-like:100000`, `nf-like:50000`, `ml-csv:path[:limit]`,
    /// `nf-file:path[:limit]`.
    pub fn parse(s: &str, seed: u64) -> Result<Self> {
        let parts: Vec<&str> = s.splitn(3, ':').collect();
        let limit = parts.get(2).map(|v| v.parse()).transpose()?;
        match parts[0] {
            "ml-like" => Ok(Self::MovielensLike {
                events: parts.get(1).map(|v| v.parse()).transpose()?.unwrap_or(100_000),
                seed,
            }),
            "nf-like" => Ok(Self::NetflixLike {
                events: parts.get(1).map(|v| v.parse()).transpose()?.unwrap_or(100_000),
                seed,
            }),
            "ml-csv" => Ok(Self::MovielensCsv {
                path: parts
                    .get(1)
                    .ok_or_else(|| anyhow::anyhow!("ml-csv needs a path"))?
                    .to_string(),
                limit,
            }),
            "nf-file" => Ok(Self::NetflixFile {
                path: parts
                    .get(1)
                    .ok_or_else(|| anyhow::anyhow!("nf-file needs a path"))?
                    .to_string(),
                limit,
            }),
            other => anyhow::bail!(
                "unknown dataset '{other}' (ml-like|nf-like|ml-csv|nf-file)"
            ),
        }
    }

    /// Dataset id used in report labels and result files.
    pub fn name(&self) -> String {
        match self {
            Self::MovielensLike { .. } => "ml-like".into(),
            Self::NetflixLike { .. } => "nf-like".into(),
            Self::MovielensCsv { .. } => "ml-25m".into(),
            Self::NetflixFile { .. } => "netflix".into(),
        }
    }

    /// The synthetic generator parameters behind this spec, if it is a
    /// synthetic one (drift transformers need the rank-level seam that
    /// only the generator provides).
    pub fn synthetic_config(&self) -> Option<SyntheticConfig> {
        match self {
            Self::MovielensLike { events, seed } => {
                Some(SyntheticConfig::movielens_like(*events, *seed))
            }
            Self::NetflixLike { events, seed } => {
                Some(SyntheticConfig::netflix_like(*events, *seed))
            }
            Self::MovielensCsv { .. } | Self::NetflixFile { .. } => None,
        }
    }

    /// Materialize the stream with a concept-drift scenario layered over
    /// it. With no configured drift shape this is exactly [`load`];
    /// shaped drift requires a synthetic spec (the transformers act on
    /// popularity ranks, which file datasets do not expose) and fails
    /// loudly otherwise.
    ///
    /// [`load`]: DatasetSpec::load
    pub fn load_with_drift(&self, drift: &DriftConfig) -> Result<Vec<Rating>> {
        if drift.kind.is_none() {
            return self.load();
        }
        match self.synthetic_config() {
            Some(cfg) => Ok(DriftStream::new(cfg, drift.clone()).collect()),
            None => anyhow::bail!(
                "drift scenarios layer over synthetic streams \
                 (ml-like|nf-like); '{}' is a file dataset",
                self.name()
            ),
        }
    }

    /// Materialize the full event stream (timestamp-ordered).
    pub fn load(&self) -> Result<Vec<Rating>> {
        match self {
            Self::MovielensLike { events, seed } => Ok(SyntheticStream::new(
                SyntheticConfig::movielens_like(*events, *seed),
            )
            .collect()),
            Self::NetflixLike { events, seed } => Ok(SyntheticStream::new(
                SyntheticConfig::netflix_like(*events, *seed),
            )
            .collect()),
            Self::MovielensCsv { path, limit } => {
                movielens::load_movielens(path, 5.0, *limit)
            }
            Self::NetflixFile { path, limit } => {
                movielens::load_netflix(path, 5.0, *limit)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_specs() {
        let d = DatasetSpec::parse("ml-like:5000", 1).unwrap();
        assert!(matches!(d, DatasetSpec::MovielensLike { events: 5000, .. }));
        assert_eq!(d.name(), "ml-like");
        assert!(DatasetSpec::parse("bogus", 1).is_err());
        assert!(DatasetSpec::parse("ml-csv", 1).is_err());
        let d = DatasetSpec::parse("nf-like", 1).unwrap();
        assert!(matches!(d, DatasetSpec::NetflixLike { events: 100_000, .. }));
    }

    #[test]
    fn loads_synthetic() {
        let d = DatasetSpec::parse("nf-like:2000", 3).unwrap();
        let events = d.load().unwrap();
        assert_eq!(events.len(), 2000);
    }

    #[test]
    fn drift_layering_over_specs() {
        let d = DatasetSpec::parse("nf-like:2000", 3).unwrap();
        let plain = d.load().unwrap();
        // No configured shape: byte-identical to the bare loader.
        assert_eq!(d.load_with_drift(&DriftConfig::none()).unwrap(), plain);
        let abrupt =
            DriftConfig::from_toml("[drift]\nkind = \"abrupt\"").unwrap();
        let drifted = d.load_with_drift(&abrupt).unwrap();
        assert_eq!(drifted.len(), 2000);
        assert_ne!(drifted, plain);
        // File datasets have no rank seam to drift on.
        let f = DatasetSpec::parse("ml-csv:/no/such.csv", 1).unwrap();
        assert!(f.load_with_drift(&abrupt).is_err());
    }
}
