//! Real-dataset loaders: MovieLens `ratings.csv` and Netflix Prize
//! `combined_data_*.txt`. If the files exist the experiment harness uses
//! them; otherwise it falls back to the synthetic generators (DESIGN.md §3).
//!
//! Both loaders apply the paper's preprocessing (Section 5.2):
//! 1. keep only 5-star ("positive") feedback,
//! 2. sort ascending by timestamp to emulate the stream.

use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{Context, Result};

use crate::data::types::Rating;
use crate::util::csv::split_line;

/// Load MovieLens `ratings.csv` (`userId,movieId,rating,timestamp`).
pub fn load_movielens<P: AsRef<Path>>(
    path: P,
    min_rating: f32,
    limit: Option<u64>,
) -> Result<Vec<Rating>> {
    let file = std::fs::File::open(path.as_ref()).with_context(|| {
        format!("opening movielens csv {}", path.as_ref().display())
    })?;
    let reader = BufReader::new(file);
    let mut out = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if lineno == 0 && line.starts_with("userId") {
            continue; // header
        }
        if line.trim().is_empty() {
            continue;
        }
        let fields = split_line(&line);
        if fields.len() < 4 {
            anyhow::bail!("line {}: expected 4 columns", lineno + 1);
        }
        let rating: f32 = fields[2].parse().with_context(|| {
            format!("line {}: bad rating '{}'", lineno + 1, fields[2])
        })?;
        if rating < min_rating {
            continue;
        }
        out.push(Rating::new(
            fields[0].parse()?,
            fields[1].parse()?,
            rating,
            fields[3].parse()?,
        ));
        if let Some(l) = limit {
            if out.len() as u64 >= l {
                break;
            }
        }
    }
    out.sort_by_key(|r| r.ts);
    Ok(out)
}

/// Load one Netflix Prize `combined_data_N.txt` file:
/// `movieId:` header lines followed by `userId,rating,date` rows.
pub fn load_netflix<P: AsRef<Path>>(
    path: P,
    min_rating: f32,
    limit: Option<u64>,
) -> Result<Vec<Rating>> {
    let file = std::fs::File::open(path.as_ref()).with_context(|| {
        format!("opening netflix file {}", path.as_ref().display())
    })?;
    let reader = BufReader::new(file);
    let mut out = Vec::new();
    let mut current_item: u64 = 0;
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(head) = line.strip_suffix(':') {
            current_item = head.parse().context("bad movie header")?;
            continue;
        }
        let fields = split_line(line);
        if fields.len() < 3 {
            anyhow::bail!("expected userId,rating,date row, got '{line}'");
        }
        let rating: f32 = fields[1].parse()?;
        if rating < min_rating {
            continue;
        }
        out.push(Rating::new(
            fields[0].parse()?,
            current_item,
            rating,
            parse_date_to_epoch(&fields[2])?,
        ));
        if let Some(l) = limit {
            if out.len() as u64 >= l {
                break;
            }
        }
    }
    out.sort_by_key(|r| r.ts);
    Ok(out)
}

/// `YYYY-MM-DD` -> unix-ish epoch seconds (civil-days algorithm; exact
/// calendar arithmetic, no external time crate needed).
fn parse_date_to_epoch(s: &str) -> Result<u64> {
    let parts: Vec<&str> = s.split('-').collect();
    if parts.len() != 3 {
        anyhow::bail!("bad date '{s}'");
    }
    let y: i64 = parts[0].parse()?;
    let m: i64 = parts[1].parse()?;
    let d: i64 = parts[2].parse()?;
    // Howard Hinnant's days_from_civil.
    let y_adj = if m <= 2 { y - 1 } else { y };
    let era = if y_adj >= 0 { y_adj } else { y_adj - 399 } / 400;
    let yoe = y_adj - era * 400;
    let doy = (153 * (if m > 2 { m - 3 } else { m + 9 }) + 2) / 5 + d - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    let days = era * 146_097 + doe - 719_468;
    Ok((days * 86_400).max(0) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(name: &str, content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("streamrec_data_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn movielens_filters_and_sorts() {
        let path = write_tmp(
            "ml.csv",
            "userId,movieId,rating,timestamp\n\
             1,10,5.0,300\n\
             2,20,3.5,100\n\
             3,30,5.0,200\n",
        );
        let rows = load_movielens(&path, 5.0, None).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].ts, 200); // sorted ascending
        assert_eq!(rows[0].item, 30);
        assert_eq!(rows[1].user, 1);
    }

    #[test]
    fn movielens_respects_limit() {
        let path = write_tmp(
            "ml2.csv",
            "userId,movieId,rating,timestamp\n\
             1,1,5.0,1\n2,2,5.0,2\n3,3,5.0,3\n",
        );
        let rows = load_movielens(&path, 5.0, Some(2)).unwrap();
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn netflix_format_parses() {
        let path = write_tmp(
            "nf.txt",
            "7:\n\
             11,5,2005-09-06\n\
             12,2,2005-09-07\n\
             8:\n\
             11,5,2004-01-01\n",
        );
        let rows = load_netflix(&path, 5.0, None).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].item, 8); // 2004 sorts before 2005
        assert_eq!(rows[1].item, 7);
        assert_eq!(rows[1].user, 11);
    }

    #[test]
    fn date_epoch_is_calendar_correct() {
        assert_eq!(parse_date_to_epoch("1970-01-01").unwrap(), 0);
        assert_eq!(parse_date_to_epoch("1970-01-02").unwrap(), 86_400);
        // 2000-03-01: leap year handled.
        let d1 = parse_date_to_epoch("2000-02-29").unwrap();
        let d2 = parse_date_to_epoch("2000-03-01").unwrap();
        assert_eq!(d2 - d1, 86_400);
        assert!(parse_date_to_epoch("2005-9").is_err());
    }
}
