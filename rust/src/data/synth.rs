//! Synthetic rating-stream generator — the dataset substitution substrate
//! (DESIGN.md §3): MovieLens-25M and the Netflix Prize set are not
//! redistributable inside this environment, so we generate
//! timestamp-ordered streams whose *distributional shape* matches Table 1:
//!
//! * heavy-tailed item popularity (Zipf) — drives `avg ratings/item`,
//! * heavy-tailed user activity (Zipf over a shuffled user order),
//! * positive-only feedback (the paper filters to 5-star ratings),
//! * concept drift: user/item latent preference rotation over time plus
//!   popularity churn (a fraction of the item ranking is re-permuted per
//!   epoch), which is what the forgetting techniques respond to.
//!
//! Every quantity the evaluation measures (recall dynamics, state growth,
//! throughput) depends on these shapes, not on the raw MovieLens bytes.
//! If the real CSVs are present, `data::movielens` loads them instead.

use crate::data::types::Rating;
use crate::util::rng::{mix64, Pcg32, Zipf};

/// Generator parameters; `movielens_like`/`netflix_like` mirror Table 1.
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    /// Human-readable dataset id used in results ("ml-like", "nf-like").
    pub name: String,
    /// Total events to emit.
    pub events: u64,
    /// Distinct user population.
    pub users: u64,
    /// Distinct item population.
    pub items: u64,
    /// Zipf exponent for item popularity (bigger = heavier head).
    pub item_s: f64,
    /// Zipf exponent for user activity.
    pub user_s: f64,
    /// Fraction of the item ranking re-permuted at each drift epoch.
    pub drift_rate: f64,
    /// Events per drift epoch (0 disables drift).
    pub drift_every: u64,
    /// Simulated event-time seconds between consecutive events.
    pub secs_per_event: f64,
    /// Base RNG seed.
    pub seed: u64,
}

impl SyntheticConfig {
    /// MovieLens-25M-shaped stream (Table 1 row 1, scaled 1:9 by default):
    /// many items relative to users' activity, avg ratings/item ≈ 133,
    /// avg ratings/user ≈ 23.
    pub fn movielens_like(events: u64, seed: u64) -> Self {
        // Keep Table 1's ratios: users = events/23.3, items = events/133.
        let users = (events as f64 / 23.3).round().max(16.0) as u64;
        let items = (events as f64 / 133.0).round().max(16.0) as u64;
        Self {
            name: "ml-like".to_string(),
            events,
            users,
            items,
            item_s: 1.05,
            user_s: 0.9,
            drift_rate: 0.05,
            drift_every: events / 10,
            secs_per_event: 17.0, // 25M ratings over ~25y -> tens of seconds
            seed,
        }
    }

    /// Netflix-Prize-shaped stream (Table 1 row 2): far fewer items, very
    /// heavy item reuse (avg ratings/item ≈ 1361), avg ratings/user ≈ 10.6.
    pub fn netflix_like(events: u64, seed: u64) -> Self {
        let users = (events as f64 / 10.6).round().max(16.0) as u64;
        let items = (events as f64 / 1361.5).round().max(16.0) as u64;
        Self {
            name: "nf-like".to_string(),
            events,
            users,
            items,
            item_s: 1.0,
            user_s: 0.8,
            drift_rate: 0.05,
            drift_every: events / 10,
            secs_per_event: 12.0,
            seed,
        }
    }
}

/// One sampled-but-unmaterialized stream element: the Zipf popularity
/// *ranks* (0 = most popular) plus the exponential inter-arrival gap.
/// This is the seam the concept-drift transformers in [`crate::data::drift`]
/// operate on — a drift shape is a deterministic function of ranks (the
/// preference distribution), not of the scrambled public ids.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RawEvent {
    /// Activity rank of the sampled user (0 = most active).
    pub user_rank: u64,
    /// Popularity rank of the sampled item (0 = most popular).
    pub item_rank: u64,
    /// Seconds between the previous event and this one.
    pub gap_secs: f64,
}

/// Iterator of timestamp-ordered rating events.
pub struct SyntheticStream {
    cfg: SyntheticConfig,
    rng: Pcg32,
    item_zipf: Zipf,
    user_zipf: Zipf,
    /// rank -> item id permutation (drift re-permutes prefixes of this).
    item_perm: Vec<u64>,
    /// rank -> user id permutation.
    user_perm: Vec<u64>,
    emitted: u64,
    clock: f64,
}

impl SyntheticStream {
    /// Build the generator for `cfg` (permutations shuffled up front).
    pub fn new(cfg: SyntheticConfig) -> Self {
        let mut rng = Pcg32::seeded(cfg.seed);
        let mut item_perm: Vec<u64> = (0..cfg.items).collect();
        let mut user_perm: Vec<u64> = (0..cfg.users).collect();
        rng.shuffle(&mut item_perm);
        rng.shuffle(&mut user_perm);
        Self {
            item_zipf: Zipf::new(cfg.items, cfg.item_s),
            user_zipf: Zipf::new(cfg.users, cfg.user_s),
            item_perm,
            user_perm,
            rng,
            emitted: 0,
            clock: 0.0,
            cfg,
        }
    }

    /// The generator parameters this stream was built with.
    pub fn config(&self) -> &SyntheticConfig {
        &self.cfg
    }

    /// Sample the next element at the *rank* level (advancing the
    /// generator's RNG, drift epochs, and event budget) without
    /// materializing ids. `next()` is exactly
    /// `sample_raw().map(|r| self.materialize(r))`, so a wrapper that
    /// transforms ranks between the two calls sees the same base stream
    /// the untransformed iterator would emit.
    pub fn sample_raw(&mut self) -> Option<RawEvent> {
        if self.emitted >= self.cfg.events {
            return None;
        }
        if self.cfg.drift_every > 0
            && self.emitted > 0
            && self.emitted % self.cfg.drift_every == 0
        {
            self.drift();
        }
        let item_rank = self.item_zipf.sample(&mut self.rng);
        let user_rank = self.user_zipf.sample(&mut self.rng);
        // Poisson-ish inter-arrival via exponential spacing.
        let u = self.rng.next_f64().max(1e-12);
        let gap_secs = -u.ln() * self.cfg.secs_per_event;
        self.emitted += 1;
        Some(RawEvent { user_rank, item_rank, gap_secs })
    }

    /// Turn a sampled (possibly transformed) rank pair into the public
    /// event: ranks map through the drifting permutations, ids are
    /// scrambled, and the stream clock advances by the gap. Ranks must be
    /// in range (`user_rank < users`, `item_rank < items`).
    pub fn materialize(&mut self, raw: RawEvent) -> Rating {
        // Scramble ids so they are not dense-rank-ordered (real ids aren't;
        // the router hashes raw ids, so id structure must not be a gift).
        let item = mix64(self.item_perm[raw.item_rank as usize]) % (1 << 40);
        let user = mix64(self.user_perm[raw.user_rank as usize] | (1 << 41))
            % (1 << 40);
        self.clock += raw.gap_secs.max(0.0);
        Rating::new(user, item, 5.0, self.clock as u64)
    }

    /// Apply one drift epoch: swap `drift_rate * items` randomly chosen
    /// ranking positions (popularity churn / concept drift).
    fn drift(&mut self) {
        let swaps = (self.cfg.items as f64 * self.cfg.drift_rate) as u64;
        for _ in 0..swaps {
            let a = self.rng.next_bounded(self.cfg.items) as usize;
            let b = self.rng.next_bounded(self.cfg.items) as usize;
            self.item_perm.swap(a, b);
        }
        // Users drift too, but more slowly (taste changes < catalog churn).
        let uswaps = (self.cfg.users as f64 * self.cfg.drift_rate * 0.25) as u64;
        for _ in 0..uswaps {
            let a = self.rng.next_bounded(self.cfg.users) as usize;
            let b = self.rng.next_bounded(self.cfg.users) as usize;
            self.user_perm.swap(a, b);
        }
    }
}

impl Iterator for SyntheticStream {
    type Item = Rating;

    fn next(&mut self) -> Option<Rating> {
        let raw = self.sample_raw()?;
        Some(self.materialize(raw))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<_> =
            SyntheticStream::new(SyntheticConfig::movielens_like(1000, 1))
                .collect();
        let b: Vec<_> =
            SyntheticStream::new(SyntheticConfig::movielens_like(1000, 1))
                .collect();
        assert_eq!(a, b);
        let c: Vec<_> =
            SyntheticStream::new(SyntheticConfig::movielens_like(1000, 2))
                .collect();
        assert_ne!(a, c);
    }

    #[test]
    fn emits_exactly_n_events_with_monotone_time() {
        let events: Vec<_> =
            SyntheticStream::new(SyntheticConfig::netflix_like(5000, 3))
                .collect();
        assert_eq!(events.len(), 5000);
        for w in events.windows(2) {
            assert!(w[1].ts >= w[0].ts, "timestamps must be non-decreasing");
        }
    }

    #[test]
    fn ml_like_shape_roughly_matches_table1() {
        let cfg = SyntheticConfig::movielens_like(200_000, 7);
        let stream = SyntheticStream::new(cfg);
        let mut per_item: HashMap<u64, u64> = HashMap::new();
        let mut per_user: HashMap<u64, u64> = HashMap::new();
        for r in stream {
            *per_item.entry(r.item).or_default() += 1;
            *per_user.entry(r.user).or_default() += 1;
        }
        let avg_item = 200_000.0 / per_item.len() as f64;
        let avg_user = 200_000.0 / per_user.len() as f64;
        // Table 1: 133 ratings/item, 23.3 ratings/user. Zipf sampling only
        // touches a subset of the population, so allow a wide band.
        assert!(avg_item > 60.0, "avg ratings/item {avg_item}");
        assert!(avg_user > 15.0, "avg ratings/user {avg_user}");
        // Heavy tail: the most popular item dwarfs the median.
        let mut counts: Vec<u64> = per_item.values().copied().collect();
        counts.sort_unstable();
        let max = *counts.last().unwrap();
        let med = counts[counts.len() / 2];
        assert!(max > med * 20, "max={max} med={med}");
    }

    #[test]
    fn nf_like_has_fewer_items_than_ml_like() {
        let ml = SyntheticConfig::movielens_like(100_000, 1);
        let nf = SyntheticConfig::netflix_like(100_000, 1);
        assert!(nf.items < ml.items / 5);
        assert!(nf.users > ml.users);
    }

    #[test]
    fn drift_changes_popular_items() {
        let mut cfg = SyntheticConfig::movielens_like(50_000, 5);
        cfg.drift_rate = 0.5;
        cfg.drift_every = 10_000;
        let stream = SyntheticStream::new(cfg);
        let mut first: HashMap<u64, u64> = HashMap::new();
        let mut last: HashMap<u64, u64> = HashMap::new();
        for (i, r) in stream.enumerate() {
            if i < 10_000 {
                *first.entry(r.item).or_default() += 1;
            } else if i >= 40_000 {
                *last.entry(r.item).or_default() += 1;
            }
        }
        let top = |m: &HashMap<u64, u64>| {
            let mut v: Vec<_> = m.iter().map(|(k, c)| (*c, *k)).collect();
            v.sort_unstable_by(|a, b| b.cmp(a));
            v.into_iter().take(20).map(|(_, k)| k).collect::<Vec<_>>()
        };
        let t1 = top(&first);
        let t2 = top(&last);
        let overlap = t1.iter().filter(|k| t2.contains(k)).count();
        assert!(overlap < 20, "drift should churn the top-20 items");
    }

    #[test]
    fn all_ratings_positive() {
        let stream =
            SyntheticStream::new(SyntheticConfig::movielens_like(1000, 9));
        for r in stream {
            assert!(r.rating >= 5.0);
        }
    }
}
