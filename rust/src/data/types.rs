//! Core event types shared by every layer of the system.

/// User identifier (dense or sparse; the router only needs integer hashes).
pub type UserId = u64;

/// Item identifier.
pub type ItemId = u64;

/// One user-item feedback element on the stream: the `<user, item, rating>`
/// tuple of the paper, plus the event timestamp used for stream ordering
/// and the LRU forgetting clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rating {
    /// The user who produced the feedback.
    pub user: UserId,
    /// The item the feedback is about.
    pub item: ItemId,
    /// Raw rating. The streaming algorithms are positive-only/binary
    /// (Section 5.2 filters to 5-star feedback), but the raw value is kept
    /// for dataset statistics and loaders.
    pub rating: f32,
    /// Event time in seconds (dataset timestamp or synthetic clock).
    pub ts: u64,
}

impl Rating {
    /// Convenience constructor in field order.
    pub fn new(user: UserId, item: ItemId, rating: f32, ts: u64) -> Self {
        Self { user, item, rating, ts }
    }
}

/// Snapshot of a worker's state-entry counts — the paper's "memory"
/// metric (Section 5.2 measures entries, not bytes).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StateSizes {
    /// Live user representations (rows of the worker-local U).
    pub users: u64,
    /// Live item representations (rows of the worker-local I).
    pub items: u64,
    /// Algorithm-specific auxiliary entries (e.g. DICS item-pair counts).
    pub aux: u64,
}

impl StateSizes {
    /// Total entries across all three stores.
    pub fn total(&self) -> u64 {
        self.users + self.items + self.aux
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_sizes_total() {
        let s = StateSizes { users: 2, items: 3, aux: 5 };
        assert_eq!(s.total(), 10);
    }
}
