//! Concept-drift scenario transformers (DESIGN note; paper §2 names
//! concept drift as one of the three streaming-RS requirements, and the
//! forgetting techniques of §5.2 exist to respond to it).
//!
//! The base [`SyntheticStream`] already carries mild background drift
//! (popularity churn per epoch). This module layers *shaped, scheduled*
//! drift on top, so experiments can ask pointed questions — "how fast
//! does the model recover from an abrupt preference flip?", "does LRU
//! forgetting track a user-churn wave?" — instead of hoping the
//! background churn happens to exercise them.
//!
//! Every transformer is a deterministic, seedable function of the
//! element's *popularity ranks* (the [`RawEvent`] seam): drift reshapes
//! the preference distribution, which is the concept that drifts, while
//! the id scrambling and routing stay untouched. Same seed ⇒ identical
//! stream, property-tested in `tests/drift_scenarios.rs`.
//!
//! Shapes (`[drift]` TOML table; see docs/CONFIG.md):
//!
//! * **abrupt** — at `at`, the item popularity ranking rotates by half
//!   the catalog in one step: yesterday's head is suddenly mid-tail.
//!   The classic sudden-drift stressor (recall dips, then recovers).
//! * **rotate** — the same rotation, but blended in gradually over
//!   `[at, end)`: each event flips to the new preference order with a
//!   probability that ramps 0 → 1. Gradual/incremental drift.
//! * **recurring** — the ranking alternates between the two orders every
//!   `period_events` events: seasonal drift, where an old concept
//!   returns and a model that forgot everything must relearn it.
//! * **invert** — at `at`, rank `r` becomes rank `items-1-r`: exact
//!   popularity inversion (the head moves to the *tail*, not mid-list —
//!   harsher than `abrupt` for popularity-following models).
//! * **churn** — from `at` on, a fixed `fraction` of the user base is
//!   replaced by brand-new user ids (stable per user, so the newcomers
//!   recur and can be learned): a user-churn + cold-start wave.
//! * **burst** — inter-arrival gaps divide by `factor` during
//!   `[at, at+len)`: an arrival-rate burst. Ranks are untouched; this
//!   stresses event-time machinery (LRU clocks) and throughput, not
//!   accuracy.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::{parse_toml_subset, TomlValue};
use crate::data::synth::{RawEvent, SyntheticConfig, SyntheticStream};
use crate::data::types::Rating;
use crate::util::rng::{mix64, Pcg32};

/// One shaped drift scenario, scheduled on the stream position. Stream
/// positions are *fractions* of the configured event budget, so the same
/// scenario file scales from a CI smoke run to a full-length experiment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DriftKind {
    /// One-step preference flip at fraction `at`: item ranks rotate by
    /// half the catalog.
    Abrupt {
        /// Stream fraction the flip happens at.
        at: f64,
    },
    /// Gradual interest rotation: between `start` and `end` each event
    /// samples the new preference order with probability ramping 0 → 1;
    /// after `end` the rotation is total.
    Rotate {
        /// Stream fraction the ramp begins at.
        start: f64,
        /// Stream fraction the ramp completes at.
        end: f64,
    },
    /// Seasonal drift: the preference order alternates every
    /// `period_events` events (phase 0 = original, phase 1 = rotated,
    /// phase 2 = original again, ...).
    Recurring {
        /// Events per phase.
        period_events: u64,
    },
    /// Exact popularity inversion at fraction `at`: rank `r` becomes
    /// `items - 1 - r`.
    Invert {
        /// Stream fraction the inversion happens at.
        at: f64,
    },
    /// User churn + cold-start wave: from `at` on, a deterministic
    /// `fraction` of users are replaced by fresh ids (stable per user).
    Churn {
        /// Stream fraction the wave starts at.
        at: f64,
        /// Fraction of the user base that churns (0..=1).
        fraction: f64,
    },
    /// Arrival-rate burst: gaps divide by `factor` inside
    /// `[at, at + len)`.
    Burst {
        /// Stream fraction the burst starts at.
        at: f64,
        /// Burst length as a stream fraction.
        len: f64,
        /// Rate multiplier (gap divisor) during the burst.
        factor: f64,
    },
}

impl DriftKind {
    /// Canonical scenario name used in labels, CSVs, and result files.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Abrupt { .. } => "abrupt",
            Self::Rotate { .. } => "rotate",
            Self::Recurring { .. } => "recurring",
            Self::Invert { .. } => "invert",
            Self::Churn { .. } => "churn",
            Self::Burst { .. } => "burst",
        }
    }

    /// First stream position at which the preference distribution
    /// changes, given the stream's event budget — the point a windowed
    /// recall curve is expected to react at.
    pub fn drift_seq(&self, total_events: u64) -> u64 {
        let frac = match self {
            Self::Abrupt { at }
            | Self::Invert { at }
            | Self::Churn { at, .. }
            | Self::Burst { at, .. } => *at,
            Self::Rotate { start, .. } => *start,
            Self::Recurring { period_events } => {
                return (*period_events).min(total_events);
            }
        };
        frac_seq(frac, total_events)
    }
}

/// Stream fraction → absolute event index (the schedule conversion every
/// drift shape and the scenario driver share).
pub fn frac_seq(frac: f64, total: u64) -> u64 {
    (frac.clamp(0.0, 1.0) * total as f64) as u64
}

/// Parsed `[drift]` configuration: at most one shaped scenario
/// (`kind = "none"` or an absent table means pass-through).
#[derive(Debug, Clone, Default)]
pub struct DriftConfig {
    /// The scheduled drift shape, if any.
    pub kind: Option<DriftKind>,
}

impl DriftConfig {
    /// Pass-through (no shaped drift).
    pub fn none() -> Self {
        Self::default()
    }

    /// Parse the `[drift]` table from TOML-subset text (other sections
    /// are ignored, so a full scenario file can be handed over whole).
    pub fn from_toml(text: &str) -> Result<Self> {
        Self::from_kv(&parse_toml_subset(text)?)
    }

    /// Parse from already-parsed `section.key -> value` pairs.
    pub fn from_kv(kv: &BTreeMap<String, TomlValue>) -> Result<Self> {
        let get = |k: &str| kv.get(k);
        let num = |k: &str, default: f64| -> Result<f64> {
            Ok(match get(k) {
                Some(v) => v.num()?,
                None => default,
            })
        };
        let kind = match get("drift.kind").map(|v| v.str()).transpose()? {
            None | Some("none") => None,
            Some("abrupt") => {
                Some(DriftKind::Abrupt { at: num("drift.at", 0.5)? })
            }
            Some("rotate") => {
                let start = num("drift.at", 0.25)?;
                Some(DriftKind::Rotate {
                    start,
                    end: num("drift.end", (start + 0.5).min(1.0))?,
                })
            }
            Some("recurring") => Some(DriftKind::Recurring {
                period_events: match get("drift.period_events") {
                    Some(v) => v.int()?.max(1) as u64,
                    None => 10_000,
                },
            }),
            Some("invert") => {
                Some(DriftKind::Invert { at: num("drift.at", 0.5)? })
            }
            Some("churn") => Some(DriftKind::Churn {
                at: num("drift.at", 0.5)?,
                fraction: num("drift.fraction", 0.5)?,
            }),
            Some("burst") => Some(DriftKind::Burst {
                at: num("drift.at", 0.5)?,
                len: num("drift.len", 0.1)?,
                factor: num("drift.factor", 8.0)?,
            }),
            Some(other) => bail!(
                "unknown drift kind '{other}' \
                 (none|abrupt|rotate|recurring|invert|churn|burst)"
            ),
        };
        let cfg = Self { kind };
        cfg.validate()?;
        Ok(cfg)
    }

    fn validate(&self) -> Result<()> {
        match self.kind {
            Some(DriftKind::Abrupt { at })
            | Some(DriftKind::Invert { at }) => check_frac("drift.at", at)?,
            Some(DriftKind::Rotate { start, end }) => {
                check_frac("drift.at", start)?;
                check_frac("drift.end", end)?;
                if end < start {
                    bail!("drift.end ({end}) must be >= drift.at ({start})");
                }
            }
            Some(DriftKind::Churn { at, fraction }) => {
                check_frac("drift.at", at)?;
                check_frac("drift.fraction", fraction)?;
            }
            Some(DriftKind::Burst { at, len, factor }) => {
                check_frac("drift.at", at)?;
                check_frac("drift.len", len)?;
                if factor <= 0.0 {
                    bail!("drift.factor must be > 0, got {factor}");
                }
            }
            Some(DriftKind::Recurring { .. }) | None => {}
        }
        Ok(())
    }
}

fn check_frac(key: &str, v: f64) -> Result<()> {
    if !(0.0..=1.0).contains(&v) {
        bail!("{key} must be a stream fraction in [0, 1], got {v}");
    }
    Ok(())
}

/// Tag salts for the churn wave's two deterministic hashes (membership
/// and identity remap); mixed with the stream seed so different seeds
/// churn different user subsets.
const CHURN_PICK_SALT: u64 = 0xC0_1D_57A7;
const CHURN_REMAP_SALT: u64 = 0x0DD_1D_5EED;

/// A [`SyntheticStream`] with a shaped drift scenario layered on top.
///
/// The wrapper intercepts each element at the rank level
/// ([`SyntheticStream::sample_raw`]), applies the scheduled transform,
/// and materializes through the untouched base generator — so without a
/// configured shape the output is *bit-identical* to the bare stream,
/// and with one, everything outside the transform (id scrambling,
/// inter-arrival sampling, background churn) is exactly the base
/// stream's.
pub struct DriftStream {
    inner: SyntheticStream,
    kind: Option<DriftKind>,
    /// Drift-private RNG (the `rotate` ramp coin); the base stream's RNG
    /// is never touched, so base randomness is shape-independent.
    rng: Pcg32,
    /// Per-seed salt for the churn hashes.
    churn_salt: u64,
    seq: u64,
    total: u64,
    items: u64,
}

impl DriftStream {
    /// Build the base generator for `cfg` and layer `drift` over it.
    pub fn new(cfg: SyntheticConfig, drift: DriftConfig) -> Self {
        Self::over(SyntheticStream::new(cfg), drift)
    }

    /// Layer `drift` over an already-built base stream.
    pub fn over(inner: SyntheticStream, drift: DriftConfig) -> Self {
        let cfg = inner.config();
        let seed = cfg.seed;
        let total = cfg.events;
        let items = cfg.items;
        Self {
            inner,
            kind: drift.kind,
            rng: Pcg32::seeded(mix64(seed ^ 0xD21F_75EE_D5)),
            churn_salt: mix64(seed ^ CHURN_REMAP_SALT),
            seq: 0,
            total,
            items,
        }
    }

    /// The configured drift shape (None = pass-through).
    pub fn kind(&self) -> Option<DriftKind> {
        self.kind
    }

    /// The base generator's parameters.
    pub fn config(&self) -> &SyntheticConfig {
        self.inner.config()
    }

    /// Rotate a popularity rank by half the catalog (the shared "new
    /// preference order" of abrupt/rotate/recurring).
    fn rotated(&self, rank: u64) -> u64 {
        if self.items <= 1 {
            rank
        } else {
            (rank + self.items / 2) % self.items
        }
    }

    /// Apply the scheduled rank/gap transform for stream position `seq`;
    /// returns the churn fraction if the churn wave is active (churn
    /// acts on the materialized user id, not the rank).
    fn transform(&mut self, seq: u64, raw: &mut RawEvent) -> Option<f64> {
        match self.kind? {
            DriftKind::Abrupt { at } => {
                if seq >= frac_seq(at, self.total) {
                    raw.item_rank = self.rotated(raw.item_rank);
                }
            }
            DriftKind::Rotate { start, end } => {
                let s = frac_seq(start, self.total);
                let e = frac_seq(end, self.total).max(s + 1);
                if seq >= e {
                    raw.item_rank = self.rotated(raw.item_rank);
                } else if seq >= s {
                    let p = (seq - s) as f64 / (e - s) as f64;
                    if self.rng.next_f64() < p {
                        raw.item_rank = self.rotated(raw.item_rank);
                    }
                }
            }
            DriftKind::Recurring { period_events } => {
                if (seq / period_events.max(1)) % 2 == 1 {
                    raw.item_rank = self.rotated(raw.item_rank);
                }
            }
            DriftKind::Invert { at } => {
                if seq >= frac_seq(at, self.total) {
                    raw.item_rank = self.items - 1 - raw.item_rank;
                }
            }
            DriftKind::Churn { at, fraction } => {
                if seq >= frac_seq(at, self.total) {
                    return Some(fraction);
                }
            }
            DriftKind::Burst { at, len, factor } => {
                let s = frac_seq(at, self.total);
                let e = frac_seq((at + len).min(1.0), self.total).max(s);
                if seq >= s && seq < e {
                    raw.gap_secs /= factor.max(1e-9);
                }
            }
        }
        None
    }
}

impl Iterator for DriftStream {
    type Item = Rating;

    fn next(&mut self) -> Option<Rating> {
        let mut raw = self.inner.sample_raw()?;
        let seq = self.seq;
        self.seq += 1;
        let churn = self.transform(seq, &mut raw);
        let mut rating = self.inner.materialize(raw);
        if let Some(fraction) = churn {
            // Deterministic membership (a fixed subset of users churns)
            // and a stable identity remap (the newcomer recurs, so the
            // model can learn it like any other cold-start user).
            let picked = mix64(rating.user ^ CHURN_PICK_SALT) % 10_000
                < (fraction * 10_000.0) as u64;
            if picked {
                rating.user =
                    mix64(rating.user ^ self.churn_salt) % (1 << 40);
            }
        }
        Some(rating)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::{HashMap, HashSet};

    fn stream(kind: Option<DriftKind>, events: u64, seed: u64) -> DriftStream {
        DriftStream::new(
            SyntheticConfig::movielens_like(events, seed),
            DriftConfig { kind },
        )
    }

    fn top_items(events: &[Rating], n: usize) -> Vec<u64> {
        let mut counts: HashMap<u64, u64> = HashMap::new();
        for e in events {
            *counts.entry(e.item).or_default() += 1;
        }
        let mut v: Vec<_> = counts.into_iter().map(|(k, c)| (c, k)).collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v.into_iter().take(n).map(|(_, k)| k).collect()
    }

    fn overlap(a: &[u64], b: &[u64]) -> usize {
        a.iter().filter(|x| b.contains(x)).count()
    }

    #[test]
    fn no_drift_is_bit_identical_to_base() {
        let base: Vec<_> =
            SyntheticStream::new(SyntheticConfig::movielens_like(3000, 9))
                .collect();
        let wrapped: Vec<_> = stream(None, 3000, 9).collect();
        assert_eq!(base, wrapped);
    }

    #[test]
    fn every_kind_is_deterministic_and_keeps_the_budget() {
        let kinds = [
            DriftKind::Abrupt { at: 0.5 },
            DriftKind::Rotate { start: 0.3, end: 0.7 },
            DriftKind::Recurring { period_events: 500 },
            DriftKind::Invert { at: 0.5 },
            DriftKind::Churn { at: 0.5, fraction: 0.5 },
            DriftKind::Burst { at: 0.4, len: 0.2, factor: 8.0 },
        ];
        for kind in kinds {
            let a: Vec<_> = stream(Some(kind), 2000, 7).collect();
            let b: Vec<_> = stream(Some(kind), 2000, 7).collect();
            assert_eq!(a, b, "{}: same seed must replay", kind.name());
            assert_eq!(a.len(), 2000, "{}: event budget", kind.name());
            for w in a.windows(2) {
                assert!(w[1].ts >= w[0].ts, "{}: monotone time", kind.name());
            }
            let c: Vec<_> = stream(Some(kind), 2000, 8).collect();
            assert_ne!(a, c, "{}: different seed differs", kind.name());
        }
    }

    #[test]
    fn abrupt_flip_churns_the_popular_head() {
        let events: Vec<_> =
            stream(Some(DriftKind::Abrupt { at: 0.5 }), 40_000, 3).collect();
        let pre = top_items(&events[..20_000], 10);
        let post = top_items(&events[20_000..], 10);
        assert!(
            overlap(&pre, &post) <= 3,
            "abrupt flip must replace the head: {} shared",
            overlap(&pre, &post)
        );
        // Prefix identical to the undrifted stream (drift is scheduled,
        // not ambient).
        let base: Vec<_> = stream(None, 40_000, 3).collect();
        assert_eq!(&events[..20_000], &base[..20_000]);
        assert_ne!(&events[20_000..], &base[20_000..]);
    }

    /// Like `stream` but with the generator's *background* popularity
    /// churn disabled, so only the scheduled drift moves the ranking.
    fn quiet_stream(kind: DriftKind, events: u64, seed: u64) -> DriftStream {
        let mut cfg = SyntheticConfig::movielens_like(events, seed);
        cfg.drift_every = 0;
        DriftStream::new(cfg, DriftConfig { kind: Some(kind) })
    }

    #[test]
    fn recurring_drift_brings_the_old_concept_back() {
        let period = 10_000u64;
        let events: Vec<_> = quiet_stream(
            DriftKind::Recurring { period_events: period },
            40_000,
            5,
        )
        .collect();
        let p0 = top_items(&events[..10_000], 10);
        let p1 = top_items(&events[10_000..20_000], 10);
        let p2 = top_items(&events[20_000..30_000], 10);
        assert!(overlap(&p0, &p1) <= 4, "phases must differ");
        assert!(
            overlap(&p0, &p2) >= 6,
            "phase 2 must recur phase 0's concept: {} shared",
            overlap(&p0, &p2)
        );
    }

    #[test]
    fn churn_wave_introduces_new_users_and_retires_old_ones() {
        let kind = DriftKind::Churn { at: 0.5, fraction: 0.6 };
        let events: Vec<_> = stream(Some(kind), 30_000, 11).collect();
        let pre: HashSet<u64> =
            events[..15_000].iter().map(|e| e.user).collect();
        let post: HashSet<u64> =
            events[15_000..].iter().map(|e| e.user).collect();
        let newcomers = post.difference(&pre).count();
        assert!(
            newcomers as f64 >= 0.3 * post.len() as f64,
            "cold-start wave too small: {newcomers}/{}",
            post.len()
        );
        // Unchurned users persist: the wave replaces a fraction, not all.
        let survivors = post.intersection(&pre).count();
        assert!(survivors > 0, "some users must survive the wave");
    }

    #[test]
    fn burst_compresses_event_time_without_touching_preferences() {
        let kind = DriftKind::Burst { at: 0.25, len: 0.5, factor: 16.0 };
        let burst: Vec<_> = stream(Some(kind), 20_000, 13).collect();
        let base: Vec<_> = stream(None, 20_000, 13).collect();
        // Same users/items in the same order — only timestamps move.
        for (a, b) in burst.iter().zip(&base) {
            assert_eq!((a.user, a.item), (b.user, b.item));
        }
        let span = |e: &[Rating]| e.last().unwrap().ts - e.first().unwrap().ts;
        let w_burst = span(&burst[5_000..15_000]);
        let w_base = span(&base[5_000..15_000]);
        assert!(
            (w_burst as f64) < 0.25 * w_base as f64,
            "burst window must compress: {w_burst} vs {w_base}"
        );
    }

    #[test]
    fn invert_moves_head_to_tail() {
        let events: Vec<_> =
            quiet_stream(DriftKind::Invert { at: 0.0 }, 30_000, 17).collect();
        let mut base_cfg = SyntheticConfig::movielens_like(30_000, 17);
        base_cfg.drift_every = 0;
        let base: Vec<_> =
            DriftStream::new(base_cfg, DriftConfig::none()).collect();
        let head = top_items(&base, 5);
        let inv_counts: HashMap<u64, u64> =
            events.iter().fold(HashMap::new(), |mut m, e| {
                *m.entry(e.item).or_default() += 1;
                m
            });
        // The base head items are now rare (they sit at the Zipf tail).
        let total = events.len() as u64;
        for item in head {
            let c = inv_counts.get(&item).copied().unwrap_or(0);
            assert!(
                c < total / 100,
                "old head item {item} still popular ({c} ratings)"
            );
        }
    }

    #[test]
    fn toml_parsing_round_trips_all_kinds() {
        let cases = [
            ("[drift]\nkind = \"none\"", None),
            (
                "[drift]\nkind = \"abrupt\"\nat = 0.4",
                Some(DriftKind::Abrupt { at: 0.4 }),
            ),
            (
                "[drift]\nkind = \"rotate\"\nat = 0.2\nend = 0.9",
                Some(DriftKind::Rotate { start: 0.2, end: 0.9 }),
            ),
            (
                "[drift]\nkind = \"recurring\"\nperiod_events = 2500",
                Some(DriftKind::Recurring { period_events: 2500 }),
            ),
            (
                "[drift]\nkind = \"invert\"",
                Some(DriftKind::Invert { at: 0.5 }),
            ),
            (
                "[drift]\nkind = \"churn\"\nat = 0.5\nfraction = 0.25",
                Some(DriftKind::Churn { at: 0.5, fraction: 0.25 }),
            ),
            (
                "[drift]\nkind = \"burst\"\nat = 0.1\nlen = 0.2\nfactor = 4.0",
                Some(DriftKind::Burst { at: 0.1, len: 0.2, factor: 4.0 }),
            ),
        ];
        for (text, expect) in cases {
            let cfg = DriftConfig::from_toml(text).unwrap();
            assert_eq!(cfg.kind, expect, "{text}");
        }
        assert!(DriftConfig::from_toml("").unwrap().kind.is_none());
    }

    #[test]
    fn toml_parsing_rejects_bad_values() {
        assert!(DriftConfig::from_toml("[drift]\nkind = \"bogus\"").is_err());
        assert!(DriftConfig::from_toml(
            "[drift]\nkind = \"abrupt\"\nat = 1.5"
        )
        .is_err());
        assert!(DriftConfig::from_toml(
            "[drift]\nkind = \"rotate\"\nat = 0.8\nend = 0.2"
        )
        .is_err());
        assert!(DriftConfig::from_toml(
            "[drift]\nkind = \"churn\"\nfraction = -0.1"
        )
        .is_err());
        assert!(DriftConfig::from_toml(
            "[drift]\nkind = \"burst\"\nfactor = 0"
        )
        .is_err());
    }

    #[test]
    fn drift_seq_points_at_the_change() {
        assert_eq!(DriftKind::Abrupt { at: 0.5 }.drift_seq(10_000), 5_000);
        assert_eq!(
            DriftKind::Rotate { start: 0.25, end: 1.0 }.drift_seq(8_000),
            2_000
        );
        assert_eq!(
            DriftKind::Recurring { period_events: 3_000 }.drift_seq(10_000),
            3_000
        );
    }
}
