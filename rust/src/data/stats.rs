//! Dataset characteristics — regenerates the paper's Table 1 columns
//! (ratings, users, items, avg ratings/user, avg ratings/item, sparsity).

use std::collections::HashSet;

use crate::data::types::Rating;

/// Table 1 row for one dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetStats {
    /// Dataset id.
    pub name: String,
    /// Total (filtered) rating events.
    pub ratings: u64,
    /// Distinct users.
    pub users: u64,
    /// Distinct items.
    pub items: u64,
    /// `ratings / users`.
    pub avg_ratings_per_user: f64,
    /// `ratings / items`.
    pub avg_ratings_per_item: f64,
    /// 1 - |R| / (|U| * |I|), as a percentage.
    pub sparsity_pct: f64,
}

impl DatasetStats {
    /// Compute over a full event slice.
    pub fn compute(name: &str, events: &[Rating]) -> Self {
        let mut users = HashSet::new();
        let mut items = HashSet::new();
        for r in events {
            users.insert(r.user);
            items.insert(r.item);
        }
        Self::from_counts(name, events.len() as u64, users.len() as u64, items.len() as u64)
    }

    /// Compute from an iterator without materializing events.
    pub fn compute_streaming(
        name: &str,
        events: impl Iterator<Item = Rating>,
    ) -> Self {
        let mut users = HashSet::new();
        let mut items = HashSet::new();
        let mut n = 0u64;
        for r in events {
            users.insert(r.user);
            items.insert(r.item);
            n += 1;
        }
        Self::from_counts(name, n, users.len() as u64, items.len() as u64)
    }

    fn from_counts(name: &str, ratings: u64, users: u64, items: u64) -> Self {
        let cells = (users as f64) * (items as f64);
        Self {
            name: name.to_string(),
            ratings,
            users,
            items,
            avg_ratings_per_user: ratings as f64 / users.max(1) as f64,
            avg_ratings_per_item: ratings as f64 / items.max(1) as f64,
            sparsity_pct: if cells > 0.0 {
                (1.0 - ratings as f64 / cells) * 100.0
            } else {
                0.0
            },
        }
    }

    /// Paper-style table row.
    pub fn table_row(&self) -> String {
        format!(
            "| {:13} | {:8} | {:7} | {:6} | {:6.1} | {:7.1} | {:6.2}% |",
            self.name,
            self.ratings,
            self.users,
            self.items,
            self.avg_ratings_per_user,
            self.avg_ratings_per_item,
            self.sparsity_pct
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computes_counts_and_sparsity() {
        let events = vec![
            Rating::new(1, 10, 5.0, 0),
            Rating::new(1, 11, 5.0, 1),
            Rating::new(2, 10, 5.0, 2),
        ];
        let s = DatasetStats::compute("t", &events);
        assert_eq!(s.ratings, 3);
        assert_eq!(s.users, 2);
        assert_eq!(s.items, 2);
        assert!((s.avg_ratings_per_user - 1.5).abs() < 1e-9);
        assert!((s.sparsity_pct - 25.0).abs() < 1e-9); // 1 - 3/4
    }

    #[test]
    fn streaming_matches_batch() {
        let events = vec![
            Rating::new(1, 10, 5.0, 0),
            Rating::new(2, 11, 5.0, 1),
            Rating::new(3, 10, 5.0, 2),
        ];
        let a = DatasetStats::compute("t", &events);
        let b = DatasetStats::compute_streaming("t", events.into_iter());
        assert_eq!(a, b);
    }
}
