//! Tiny benchmark harness (the offline build has no criterion crate;
//! DESIGN.md §3). Provides warmup + timed iterations with mean / p50 /
//! p99 reporting, and a `black_box` to defeat const-folding.

use std::time::{Duration, Instant};

use crate::util::histogram::Histogram;

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Timed iterations run.
    pub iters: u64,
    /// Mean ns per iteration (per item for `bench_batch`).
    pub mean_ns: f64,
    /// Median ns per iteration.
    pub p50_ns: u64,
    /// 99th-percentile ns per iteration.
    pub p99_ns: u64,
    /// Iterations (items) per second implied by the mean.
    pub throughput_per_sec: f64,
}

impl BenchResult {
    /// Print the one-line result row.
    pub fn report(&self) {
        println!(
            "{:40} {:>12.1} ns/iter  p50={:>10} p99={:>10}  ({:.2e}/s)",
            self.name,
            self.mean_ns,
            self.p50_ns,
            self.p99_ns,
            self.throughput_per_sec
        );
    }
}

/// Benchmark a closure: `warmup` untimed runs, then timed runs until
/// `budget` elapses (at least `min_iters`). Each iteration is timed
/// individually, so p50/p99 are meaningful.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: u64,
    min_iters: u64,
    budget: Duration,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut hist = Histogram::new();
    let start = Instant::now();
    let mut iters = 0u64;
    let mut total_ns = 0u64;
    while iters < min_iters || start.elapsed() < budget {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as u64;
        hist.record(dt);
        total_ns += dt;
        iters += 1;
        if iters > 50_000_000 {
            break; // sanity cap
        }
    }
    let mean_ns = total_ns as f64 / iters as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns,
        p50_ns: hist.quantile(0.5),
        p99_ns: hist.quantile(0.99),
        throughput_per_sec: 1e9 / mean_ns.max(1e-9),
    };
    r.report();
    r
}

/// Benchmark a batch closure where one call processes `batch` items;
/// reports per-item numbers.
pub fn bench_batch<F: FnMut()>(
    name: &str,
    batch: u64,
    warmup: u64,
    min_iters: u64,
    budget: Duration,
    f: F,
) -> BenchResult {
    let mut r = bench(name, warmup, min_iters, budget, f);
    r.mean_ns /= batch as f64;
    r.p50_ns /= batch;
    r.p99_ns /= batch;
    r.throughput_per_sec = 1e9 / r.mean_ns.max(1e-9);
    println!(
        "  -> per item: {:.1} ns ({:.2e} items/s)",
        r.mean_ns, r.throughput_per_sec
    );
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut acc = 0u64;
        let r = bench(
            "noop",
            2,
            50,
            Duration::from_millis(5),
            || {
                acc = black_box(acc.wrapping_add(1));
            },
        );
        assert!(r.iters >= 50);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }
}
