//! Concept-drift scenario: how the LRU/LFU forgetting techniques respond
//! when user taste and catalog popularity churn hard (the motivation in
//! Section 1 and the Section 5.2 forgetting experiments).
//!
//! Generates a high-drift Netflix-shaped stream (50% of the popularity
//! ranking re-permuted every 10% of the stream), then runs DISGD n_i=2
//! with no forgetting, LRU, and LFU, comparing recall and state growth.
//!
//! ```text
//! cargo run --release --example drift_forgetting
//! ```

use streamrec::config::{Forgetting, RunConfig, Topology};
use streamrec::coordinator::run_pipeline;
use streamrec::data::synth::{SyntheticConfig, SyntheticStream};

fn main() -> anyhow::Result<()> {
    streamrec::util::logging::init();
    let mut gen_cfg = SyntheticConfig::netflix_like(40_000, 7);
    gen_cfg.drift_rate = 0.5; // violent churn
    gen_cfg.drift_every = 4_000;
    let events: Vec<_> = SyntheticStream::new(gen_cfg).collect();
    println!("generated {} high-drift nf-like events", events.len());

    let policies: [(&str, Forgetting); 3] = [
        ("none", Forgetting::None),
        (
            "lru",
            Forgetting::Lru { trigger_secs: 43_200, max_idle_secs: 2 * 86_400 },
        ),
        (
            "lfu",
            Forgetting::Lfu { trigger_events: 2_000, min_freq: 2 },
        ),
    ];

    println!(
        "\n{:>6}  {:>10} {:>12} {:>12} {:>12} {:>8} {:>8}",
        "policy", "recall", "ev/s", "users/wrk", "items/wrk", "sweeps", "evicted"
    );
    for (name, forgetting) in policies {
        let cfg = RunConfig {
            topology: Topology::new(2, 0)?,
            forgetting,
            sample_every: 500,
            ..RunConfig::default()
        };
        let r = run_pipeline(&cfg, &events, &format!("drift-{name}"))?;
        let sweeps: u64 = r.workers.iter().map(|w| w.sweeps).sum();
        let evicted: u64 = r.workers.iter().map(|w| w.evicted).sum();
        println!(
            "{name:>6}  {:>10.4} {:>12.0} {:>12.1} {:>12.1} {sweeps:>8} {evicted:>8}",
            r.avg_recall,
            r.throughput,
            r.mean_user_state(),
            r.mean_item_state(),
        );
    }
    println!(
        "\nExpected shape (paper Figs 5-7): forgetting keeps recall at or \
         above the no-forgetting run under drift, with far smaller state; \
         aggressive LFU trades some recall for the biggest memory cut."
    );
    Ok(())
}
