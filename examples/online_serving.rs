//! Online serving: the read path the paper implies but never ships.
//!
//! Spawns a DISGD cluster (n_i = 2 -> 4 shared-nothing workers) and keeps
//! it alive over the stream: the learning loop ingests rating events
//! through the Algorithm-1 router while the serving loop answers top-10
//! queries for a panel of users. Each query fans out to the user's `n_i`
//! replicas (its grid column), every replica ranks from its *local*
//! model, and the coordinator merges the lists rank-aware — excluding
//! items the user has rated on any replica. Live metrics snapshots show
//! learning progress without stopping anything.
//!
//! # Throughput tuning
//!
//! Ingest is micro-batched: `ingest`/`ingest_batch` buffer routed events
//! per worker and flush a buffer with one bulk channel send once it holds
//! `ingest_batch_size` events (`engine.ingest_batch_size` in TOML). Two
//! things to know when tuning it:
//!
//! * **The flush-on-query rule** means you can raise it freely without
//!   losing read-your-writes: every buffer is flushed before a
//!   `recommend` or `metrics` probe goes out, so a query always observes
//!   all prior ingest — results are identical at any batch size.
//! * **Prefer `ingest_batch` over per-event `ingest`** when you already
//!   hold a slice of events (as below): identical semantics, but the
//!   buffers fill in one tight routing loop.
//!
//! Sweep the knob with `cargo run --release --bench pipeline` (records
//! `BENCH_ingest.json`); the final report's `backpressure_ns` /
//! `recv_blocked_ns` / `mean_send_batch` show what the transport paid.
//!
//! ```text
//! cargo run --release --example online_serving
//! ```

use streamrec::config::{RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::DatasetSpec;

fn main() -> anyhow::Result<()> {
    streamrec::util::logging::init();
    let events = DatasetSpec::parse("ml-like:30000", 7)?.load()?;

    let cfg = RunConfig {
        topology: Topology::new(2, 0)?,
        sample_every: 1000,
        // Micro-batched ingest: flushed early by every recommend/metrics
        // probe below, so serving freshness is unaffected.
        ingest_batch_size: 256,
        ..RunConfig::default()
    };
    let mut cluster = Cluster::spawn_labeled(&cfg, "online-serving")?;
    println!(
        "cluster up: {} workers (n_i={} item rows x {} user columns)",
        cluster.n_workers(),
        cluster.router().n_i(),
        cluster.router().n_ciw()
    );

    // A small panel of users to serve while the stream runs.
    let panel: Vec<u64> = {
        let mut seen = Vec::new();
        for e in &events {
            if !seen.contains(&e.user) {
                seen.push(e.user);
            }
            if seen.len() == 3 {
                break;
            }
        }
        seen
    };
    for &u in &panel {
        println!(
            "user {u:>6} replicated on workers {:?}",
            cluster.router().user_workers(u)
        );
    }

    for chunk in events.chunks(6000) {
        cluster.ingest_batch(chunk)?;
        let live = cluster.metrics()?;
        println!(
            "\n-- {} events in, recall {:.4}, {} queries served --",
            live.processed, live.recall, live.queries
        );
        for &u in &panel {
            let recs = cluster.recommend(u, 10)?;
            println!("   top-10 for user {u:>6}: {recs:?}");
        }
    }

    let report = cluster.finish()?;
    println!("\nfinal: {}", report.summary());
    println!(
        "profile: recommend {:.1}ms / update {:.1}ms across workers",
        report.workers.iter().map(|w| w.recommend_ns).sum::<u64>() as f64
            / 1e6,
        report.workers.iter().map(|w| w.update_ns).sum::<u64>() as f64 / 1e6,
    );
    println!(
        "transport: backpressure {:.1}ms, recv wait {:.1}ms, \
         mean send batch {:.1}",
        report.backpressure_ns as f64 / 1e6,
        report.recv_blocked_ns as f64 / 1e6,
        report.mean_send_batch,
    );
    Ok(())
}
