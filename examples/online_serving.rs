//! Online serving: the read path the paper implies but never ships —
//! plus a live mid-stream scale-out.
//!
//! Spawns a DISGD cluster (n_i = 2 -> 4 shared-nothing workers) and keeps
//! it alive over the stream: the learning loop ingests rating events
//! through the Algorithm-1 router while the serving loop answers top-10
//! queries for a panel of users. Each query fans out to the user's `n_i`
//! replicas (its grid column), every replica ranks from its *local*
//! model state, and the coordinator merges the lists rank-aware —
//! excluding items the user has rated on any replica. Live metrics
//! snapshots show learning progress without stopping anything.
//!
//! Halfway through, load "doubles" and the cluster rescales live to
//! n_i = 4 (4 -> 16 workers). The spawn config reserves the headroom
//! with `rescale_max_n_i = 4` (the Flink max-parallelism analog): model
//! state is partitioned on a fixed 4x4 grid of lanes, so the rescale
//! moves whole lanes between workers — zero events lost, and the panel's
//! recommendations immediately after the cutover are identical to the
//! ones immediately before (see ARCHITECTURE.md and
//! `tests/rescale_equivalence.rs`).
//!
//! Then, three quarters through the stream, chaos strikes: a worker is
//! killed mid-event (a deterministic injected panic via
//! `fault.chaos_kill_seq`). Because the session runs with
//! `fault.checkpoint_interval` set, the supervisor detects the crash,
//! respawns the worker, restores its lanes from their latest
//! checkpoints, and replays the missing suffix from the replay log —
//! the demo asserts that not a single event was lost and serving just
//! keeps answering (see `tests/fault_tolerance.rs` for the
//! exactly-once proof).
//!
//! # Throughput tuning
//!
//! Ingest is micro-batched: `ingest`/`ingest_batch` buffer routed events
//! per worker and flush a buffer with one bulk channel send once it holds
//! `ingest_batch_size` events (`engine.ingest_batch_size` in TOML).
//! Raising it never trades away consistency: a `recommend` flushes the
//! queried user's replica buffers and carries a read-your-writes fence,
//! so reads observe all prior ingest for that user at any batch size —
//! while a `metrics` probe flushes nothing at all and reports
//! `processed + buffered == ingested` (call `Cluster::flush` when the
//! exact split matters). Sweep the knob with
//! `cargo run --release --bench pipeline` (`BENCH_ingest.json`);
//! rescale pause costs with `--bench rescale` (`BENCH_rescale.json`).
//!
//! ```text
//! cargo run --release --example online_serving
//! ```

use streamrec::config::{RunConfig, Topology};
use streamrec::coordinator::{Cluster, ClusterMetrics};
use streamrec::data::DatasetSpec;

fn print_metrics(tag: &str, m: &ClusterMetrics) {
    println!(
        "   [{tag}] epoch {} | {} workers | processed {} | recall {:.4} | \
         queries {} | rescales {} ({} bytes moved, {:.2} ms paused)",
        m.router_epoch,
        m.workers.len(),
        m.processed,
        m.recall,
        m.queries,
        m.rescales,
        m.migrated_bytes,
        m.rescale_pause_ns as f64 / 1e6,
    );
    for w in &m.workers {
        log::debug!(
            "      worker {:>2}: {} lanes, {} events, state {:?}",
            w.worker_id,
            w.lanes,
            w.processed,
            w.state
        );
    }
}

fn main() -> anyhow::Result<()> {
    streamrec::util::logging::init();
    let events = DatasetSpec::parse("ml-like:30000", 7)?.load()?;

    // Chaos: kill whichever worker processes the event at 3/4 of the
    // stream — reproducibly, mid-serving, on the post-rescale topology.
    let kill_at = events.len() as u64 * 3 / 4;
    let cfg = RunConfig {
        topology: Topology::new(2, 0)?,
        // Headroom to grow to n_i = 4 later: state lives on a fixed 4x4
        // lane grid from the start (16 lanes over however many workers).
        rescale_max_n_i: 4,
        sample_every: 1000,
        // Micro-batched ingest: a recommend flushes the queried user's
        // replica buffers (fenced), so serving freshness is unaffected.
        ingest_batch_size: 256,
        // Fault tolerance: checkpoint every lane every 256 of its events
        // so the injected crash below is recovered exactly-once.
        fault_checkpoint_interval: 256,
        fault_chaos_kill_seq: Some(kill_at),
        ..RunConfig::default()
    };
    let mut cluster = Cluster::spawn_labeled(&cfg, "online-serving")?;
    println!(
        "cluster up: {} workers (n_i={} item rows x {} user columns), \
         state grid {}x{} ({} lanes)",
        cluster.n_workers(),
        cluster.router().n_i(),
        cluster.router().n_ciw(),
        cluster.state_grid().v_i(),
        cluster.state_grid().v_u(),
        cluster.state_grid().n_lanes(),
    );

    // A small panel of users to serve while the stream runs.
    let panel: Vec<u64> = {
        let mut seen = Vec::new();
        for e in &events {
            if !seen.contains(&e.user) {
                seen.push(e.user);
            }
            if seen.len() == 3 {
                break;
            }
        }
        seen
    };
    for &u in &panel {
        println!(
            "user {u:>6} replicated on workers {:?}",
            cluster.router().user_workers(u)
        );
    }

    let (first_half, second_half) = events.split_at(events.len() / 2);
    for chunk in first_half.chunks(5000) {
        cluster.ingest_batch(chunk)?;
        let live = cluster.metrics()?;
        println!("\n-- {} events in --", live.processed);
        for &u in &panel {
            let recs = cluster.recommend(u, 10)?;
            println!("   top-10 for user {u:>6}: {recs:?}");
        }
        print_metrics("live", &live);
    }

    // ---- Mid-stream scale-out: n_i 2 -> 4 (4 -> 16 workers). ----
    println!("\n== load doubled: rescaling n_i 2 -> 4 ==");
    // metrics() observes without flushing; flush explicitly so the
    // zero-loss comparison across the cutover is exact.
    cluster.flush()?;
    let before = cluster.metrics()?;
    print_metrics("before", &before);
    let panel_before: Vec<Vec<u64>> = panel
        .iter()
        .map(|&u| cluster.recommend(u, 10))
        .collect::<Result<_, _>>()?;

    let stats = cluster.rescale(Topology::new(4, 0)?)?;
    println!(
        "   cutover: {} -> {} workers, {} lanes / {} bytes moved, \
         paused {:.2} ms",
        stats.from_workers,
        stats.to_workers,
        stats.lanes_moved,
        stats.bytes_moved,
        stats.pause_ns as f64 / 1e6,
    );

    let after = cluster.metrics()?;
    print_metrics("after", &after);
    assert_eq!(after.processed, before.processed, "zero events lost");
    for (&u, want) in panel.iter().zip(panel_before.iter()) {
        let got = cluster.recommend(u, 10)?;
        assert_eq!(&got, want, "user {u}: answers must survive the cutover");
        println!(
            "   user {u:>6} now on workers {:?} — same top-10 ✓",
            cluster.router().user_workers(u)
        );
    }

    // ---- Keep streaming on the larger grid — a chaos kill is armed at
    // event {kill_at}; ingest and serving must not notice. ----
    println!(
        "\n== chaos armed: the worker processing event {kill_at} will \
         panic ==",
    );
    let mut seen_recovery = false;
    for chunk in second_half.chunks(5000) {
        cluster.ingest_batch(chunk)?;
        let live = cluster.metrics()?;
        println!("\n-- {} events in ({} workers) --", live.processed, live.workers.len());
        assert_eq!(
            live.processed + live.buffered,
            cluster.ingested(),
            "every accepted event is processed or buffered — even \
             across a crash"
        );
        if live.recoveries > 0 && !seen_recovery {
            seen_recovery = true;
            println!(
                "   !! worker crashed at event {kill_at} and was recovered: \
                 {} events replayed from the log, paused {:.2} ms \
                 ({} checkpoint bytes banked)",
                live.replayed_events,
                live.recovery_pause_ns as f64 / 1e6,
                live.checkpoint_bytes,
            );
        }
        for &u in &panel {
            let recs = cluster.recommend(u, 10)?;
            println!("   top-10 for user {u:>6}: {recs:?}");
        }
    }
    assert!(seen_recovery, "the injected kill must have fired");

    let report = cluster.finish()?;
    println!("\nfinal: {}", report.summary());
    println!(
        "rescales: {} ({} bytes moved, {:.2} ms total pause); \
         retired workers kept in the report: {}",
        report.rescales,
        report.migrated_bytes,
        report.rescale_pause_ns as f64 / 1e6,
        report.retired.len(),
    );
    println!(
        "recoveries: {} ({} events replayed, {:.2} ms total pause, \
         {} checkpoint bytes)",
        report.recoveries,
        report.replayed_events,
        report.recovery_pause_ns as f64 / 1e6,
        report.checkpoint_bytes,
    );
    assert_eq!(report.events, events.len() as u64, "zero loss end to end");
    println!(
        "profile: recommend {:.1}ms / update {:.1}ms across live+retired \
         workers",
        report
            .workers
            .iter()
            .chain(report.retired.iter())
            .map(|w| w.recommend_ns)
            .sum::<u64>() as f64
            / 1e6,
        report
            .workers
            .iter()
            .chain(report.retired.iter())
            .map(|w| w.update_ns)
            .sum::<u64>() as f64
            / 1e6,
    );
    println!(
        "transport: backpressure {:.1}ms, recv wait {:.1}ms, \
         mean send batch {:.1}",
        report.backpressure_ns as f64 / 1e6,
        report.recv_blocked_ns as f64 / 1e6,
        report.mean_send_batch,
    );
    Ok(())
}
