//! End-to-end quickstart: the full three-layer system on a small real
//! workload, driven through the long-lived `Cluster` session API
//! (ingest -> recommend -> metrics -> finish).
//!
//! Runs the prequential stream over a MovieLens-shaped synthetic workload
//! twice — centralized ISGD baseline and DISGD with n_i = 2 (4 workers) —
//! with the **PJRT backend** for the central run, so every layer composes:
//! Pallas kernels -> JAX model -> HLO artifacts -> PJRT execution from the
//! Rust coordinator hot path. The distributed session interleaves online
//! recommendation queries and live metrics with ingest, then logs the
//! paper's headline comparison.
//!
//! Migration note: the old one-shot `run_pipeline(&cfg, &events, label)`
//! still exists and is exactly `Cluster::spawn_labeled` + `ingest_batch`
//! + `finish`.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use streamrec::config::{Backend, RunConfig, Topology};
use streamrec::coordinator::Cluster;
use streamrec::data::DatasetSpec;
use streamrec::eval::RunReport;

fn main() -> anyhow::Result<()> {
    streamrec::util::logging::init();
    let events = DatasetSpec::parse("ml-like:20000", 42)?.load()?;
    println!("loaded {} synthetic ml-like events", events.len());

    // 1) Central ISGD on the AOT/PJRT path (Layers 1+2+3 composed).
    let pjrt_available = std::path::Path::new("artifacts/manifest.json").exists();
    let central_cfg = RunConfig {
        backend: if pjrt_available { Backend::Pjrt } else { Backend::Native },
        topology: Topology::central(),
        sample_every: 500,
        ..RunConfig::default()
    };
    if !pjrt_available {
        eprintln!("artifacts/ missing — run `make artifacts` for the PJRT path");
    }
    let mut central_cluster =
        Cluster::spawn_labeled(&central_cfg, "central-isgd")?;
    central_cluster.ingest_batch(&events)?;
    let central = central_cluster.finish()?;
    println!("\n== central ISGD ({} backend) ==", central_cfg.backend.name());
    println!("{}", central.summary());

    // 2) DISGD, n_i = 2 -> 4 shared-nothing workers, as a live session:
    //    ingest in chunks and serve a hot user's top-10 while training.
    let dist_cfg = RunConfig {
        topology: Topology::new(2, 0)?,
        sample_every: 500,
        ..RunConfig::default()
    };
    let mut cluster = Cluster::spawn_labeled(&dist_cfg, "disgd-ni2")?;
    let hot_user = events[0].user;
    println!(
        "\n== DISGD n_i=2 (4 workers), live session for user {hot_user} \
         (replicas {:?}) ==",
        cluster.router().user_workers(hot_user)
    );
    for chunk in events.chunks(5000) {
        cluster.ingest_batch(chunk)?;
        let recs = cluster.recommend(hot_user, 10)?;
        let live = cluster.metrics()?;
        println!(
            "  after {:>6} events: recall={:.4}  top-10 for {hot_user}: {recs:?}",
            live.processed, live.recall
        );
    }
    let dist = cluster.finish()?;
    println!("{}", dist.summary());

    // 3) The paper's headline comparison.
    println!("\n== recall curve (moving avg @ window 5000) ==");
    println!("{:>8}  {:>10}  {:>10}", "seq", "central", "disgd-ni2");
    let pick = |r: &RunReport, seq: u64| {
        r.recall_curve
            .iter()
            .min_by_key(|(s, _)| s.abs_diff(seq))
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    };
    for seq in (0..=events.len() as u64).step_by(2500) {
        println!(
            "{seq:>8}  {:>10.4}  {:>10.4}",
            pick(&central, seq),
            pick(&dist, seq)
        );
    }
    println!(
        "\nrecall:     central={:.4}  disgd={:.4}  ({:+.1}%)",
        central.avg_recall,
        dist.avg_recall,
        (dist.avg_recall / central.avg_recall.max(1e-9) - 1.0) * 100.0
    );
    // Note: the DISGD window includes the interleaved serving/metrics
    // round-trips above (4 fan-outs over 20k events — sub-percent), while
    // the central run is pure ingest.
    println!(
        "throughput: central={:.0} ev/s  disgd={:.0} ev/s  ({:.1}x)",
        central.throughput,
        dist.throughput,
        dist.throughput / central.throughput.max(1e-9)
    );
    println!(
        "state/worker: central users={:.0} items={:.0}  |  disgd users={:.0} items={:.0}",
        central.mean_user_state(),
        central.mean_item_state(),
        dist.mean_user_state(),
        dist.mean_item_state()
    );
    Ok(())
}
