//! End-to-end quickstart: the full three-layer system on a small real
//! workload.
//!
//! Runs the prequential pipeline over a MovieLens-shaped synthetic stream
//! twice — centralized ISGD baseline and DISGD with n_i = 2 (4 workers) —
//! with the **PJRT backend** for the central run, so every layer composes:
//! Pallas kernels -> JAX model -> HLO artifacts -> PJRT execution from the
//! Rust coordinator hot path. Logs the loss-equivalent (online recall)
//! curve and the paper's headline comparison.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use streamrec::config::{Backend, RunConfig, Topology};
use streamrec::coordinator::run_pipeline;
use streamrec::data::DatasetSpec;

fn main() -> anyhow::Result<()> {
    streamrec::util::logging::init();
    let events = DatasetSpec::parse("ml-like:20000", 42)?.load()?;
    println!("loaded {} synthetic ml-like events", events.len());

    // 1) Central ISGD on the AOT/PJRT path (Layers 1+2+3 composed).
    let pjrt_available = std::path::Path::new("artifacts/manifest.json").exists();
    let central_cfg = RunConfig {
        backend: if pjrt_available { Backend::Pjrt } else { Backend::Native },
        topology: Topology::central(),
        sample_every: 500,
        ..RunConfig::default()
    };
    if !pjrt_available {
        eprintln!("artifacts/ missing — run `make artifacts` for the PJRT path");
    }
    let central = run_pipeline(&central_cfg, &events, "central-isgd")?;
    println!("\n== central ISGD ({} backend) ==", central_cfg.backend.name());
    println!("{}", central.summary());

    // 2) DISGD, n_i = 2 -> 4 shared-nothing workers.
    let dist_cfg = RunConfig {
        topology: Topology::new(2, 0)?,
        sample_every: 500,
        ..RunConfig::default()
    };
    let dist = run_pipeline(&dist_cfg, &events, "disgd-ni2")?;
    println!("\n== DISGD n_i=2 (4 workers) ==");
    println!("{}", dist.summary());

    // 3) The paper's headline comparison.
    println!("\n== recall curve (moving avg @ window 5000) ==");
    println!("{:>8}  {:>10}  {:>10}", "seq", "central", "disgd-ni2");
    let pick = |r: &streamrec::eval::RunReport, seq: u64| {
        r.recall_curve
            .iter()
            .min_by_key(|(s, _)| s.abs_diff(seq))
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    };
    for seq in (0..=events.len() as u64).step_by(2500) {
        println!(
            "{seq:>8}  {:>10.4}  {:>10.4}",
            pick(&central, seq),
            pick(&dist, seq)
        );
    }
    println!(
        "\nrecall:     central={:.4}  disgd={:.4}  ({:+.1}%)",
        central.avg_recall,
        dist.avg_recall,
        (dist.avg_recall / central.avg_recall.max(1e-9) - 1.0) * 100.0
    );
    println!(
        "throughput: central={:.0} ev/s  disgd={:.0} ev/s  ({:.1}x)",
        central.throughput,
        dist.throughput,
        dist.throughput / central.throughput.max(1e-9)
    );
    println!(
        "state/worker: central users={:.0} items={:.0}  |  disgd users={:.0} items={:.0}",
        central.mean_user_state(),
        central.mean_item_state(),
        dist.mean_user_state(),
        dist.mean_item_state()
    );
    Ok(())
}
