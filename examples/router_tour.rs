//! A tour of the splitting & replication mechanism (Algorithm 1):
//! prints the worker grid, replica sets, and the load balance the router
//! produces over a skewed synthetic stream — the best way to *see*
//! Section 4 before running full pipelines.
//!
//! ```text
//! cargo run --release --example router_tour
//! ```

use streamrec::config::Topology;
use streamrec::coordinator::Router;
use streamrec::data::DatasetSpec;

fn main() -> anyhow::Result<()> {
    let topo = Topology::new(3, 1)?; // n_c = 9 + 3 = 12, grid 3 x 4
    let router = Router::new(topo);
    println!(
        "topology: n_i={} w={} -> n_c={} workers (grid {} item-rows x {} user-cols)\n",
        topo.n_i,
        topo.w,
        topo.n_c(),
        router.n_i(),
        router.n_ciw()
    );

    println!("replica sets (the 'replication' in splitting & replication):");
    for item in [100u64, 101, 102] {
        println!("  item {item:>4} lives on workers {:?}", router.item_workers(item));
    }
    for user in [7u64, 8] {
        println!("  user {user:>4} lives on workers {:?}", router.user_workers(user));
    }

    println!("\nrouting examples (pair -> exactly one worker):");
    for (u, i) in [(7u64, 100u64), (7, 101), (8, 100), (8, 102)] {
        println!("  <user {u}, item {i}> -> worker {}", router.route(u, i));
    }

    // Load balance over a realistic zipf-skewed stream.
    let events = DatasetSpec::parse("ml-like:50000", 3)?.load()?;
    let mut load = vec![0u64; router.n_c()];
    for e in &events {
        load[router.route(e.user, e.item)] += 1;
    }
    println!("\nload balance over {} zipf-skewed events:", events.len());
    let mean = events.len() as f64 / load.len() as f64;
    for (w, n) in load.iter().enumerate() {
        let bar = "#".repeat((*n as f64 / mean * 20.0) as usize);
        println!("  worker {w:>2}: {n:>7}  {bar}");
    }
    let max = *load.iter().max().unwrap() as f64;
    let min = *load.iter().min().unwrap() as f64;
    println!(
        "  imbalance max/min = {:.2} (skew survives hashing — the paper's \
         future-work load-rebalancing observation)",
        max / min.max(1.0)
    );
    Ok(())
}
