//! Scalability scenario: throughput and per-worker state as the
//! replication factor grows (the paper's Fig 8 in miniature), for both
//! DISGD and DICS.
//!
//! ```text
//! cargo run --release --example scaling_throughput
//! ```

use streamrec::config::{Algorithm, RunConfig, Topology};
use streamrec::coordinator::run_pipeline;
use streamrec::data::DatasetSpec;

fn main() -> anyhow::Result<()> {
    streamrec::util::logging::init();
    let events = DatasetSpec::parse("nf-like:30000", 13)?.load()?;
    println!("loaded {} nf-like events\n", events.len());

    for algo in [Algorithm::Isgd, Algorithm::Cosine] {
        println!("== {} ==", algo.name());
        println!(
            "{:>8} {:>9} {:>12} {:>10} {:>12} {:>12}",
            "n_i", "workers", "ev/s", "speedup", "recall", "users/wrk"
        );
        let mut base = None;
        for n_i in [1u64, 2, 4, 6] {
            let cfg = RunConfig {
                algorithm: algo,
                topology: Topology::new(n_i, 0)?,
                sample_every: 1000,
                ..RunConfig::default()
            };
            // Mirror the paper: the central cosine baseline cannot keep up;
            // cap it rather than waiting forever (Section 5.3.2).
            let slice = if algo == Algorithm::Cosine && n_i == 1 {
                &events[..events.len().min(6000)]
            } else {
                &events[..]
            };
            let r = run_pipeline(
                &cfg,
                slice,
                &format!("{}-ni{}", algo.name(), n_i),
            )?;
            let thpt = r.throughput;
            let speedup = match base {
                None => {
                    base = Some(thpt);
                    1.0
                }
                Some(b) => thpt / b,
            };
            println!(
                "{n_i:>8} {:>9} {thpt:>12.0} {speedup:>9.1}x {:>12.4} {:>12.1}{}",
                r.n_workers,
                r.avg_recall,
                r.mean_user_state(),
                if slice.len() != events.len() { "  (capped)" } else { "" }
            );
        }
        println!();
    }
    println!(
        "Expected shape (paper Figs 8/14): throughput grows with n_i for \
         both algorithms; DICS gains are larger relative to its central \
         baseline (which, as in the paper, cannot finish the stream)."
    );
    Ok(())
}
