#!/usr/bin/env bash
# Record the benchmark JSON files committed at the repo root.
#
# Each BENCH_*.json starts life as a stub ("status": "not yet recorded");
# the corresponding bench binary overwrites it with measured rows. The
# benches write to the *current working directory*, so this script must
# run from the repo root (it cd's there itself).
#
# BENCH_drift.json is NOT recorded here: it comes from the experiment
# driver (`streamrec experiment --config configs/drift_paper.toml`),
# not from a cargo bench target.
#
# Usage:
#   scripts/record_bench.sh                    # all recorded benches, full shapes
#   scripts/record_bench.sh transport          # just one
#   scripts/record_bench.sh --smoke hotpath    # CI shapes (<BENCH>_BENCH_SMOKE=1)
#   scripts/record_bench.sh --smoke --check …  # also fail on a throughput
#                                              # regression vs the committed JSON
#
# --check compares the best per-second figure in the freshly recorded
# file against the best figure in the committed file (skipped when the
# committed file is still a stub or was recorded at a different
# smoke/full shape). The gate is deliberately loose — it catches
# order-of-magnitude regressions, not noise: fail when
#   new_max < old_max * (1 - RECORD_BENCH_CHECK_TOLERANCE)   (default 0.6)

set -euo pipefail
cd "$(dirname "$0")/.."

# bench name -> file it records
declare -A RECORDS=(
  [pipeline]=BENCH_ingest.json
  [rescale]=BENCH_rescale.json
  [recovery]=BENCH_recovery.json
  [transport]=BENCH_transport.json
  [serving]=BENCH_serving.json
  [hotpath]=BENCH_hotpath.json
  [memory]=BENCH_memory.json
)

smoke=0
check=0
benches=()
for arg in "$@"; do
  case "$arg" in
    --smoke) smoke=1 ;;
    --check) check=1 ;;
    --*) echo "unknown flag '$arg'" >&2; exit 1 ;;
    *) benches+=("$arg") ;;
  esac
done
if [ ${#benches[@]} -eq 0 ]; then
  benches=(pipeline rescale recovery transport serving hotpath memory)
fi

# Best "per second" figure in a recorded file (rows use throughput_ev_s,
# throughput_per_sec, or per_sec depending on the bench). Prints 0 when
# the file has none.
best_rate() {
  grep -oE '"(throughput_ev_s|throughput_per_sec|per_sec)": *-?[0-9.eE+-]+' "$1" \
    | awk -F': *' 'BEGIN { m = 0 } { if ($2 + 0 > m) m = $2 + 0 } END { print m }'
}

# Smoke/full shape tag of a recorded file ("" when absent, i.e. stubs or
# pre-smoke recordings).
shape_of() {
  grep -oE '"smoke": *[0-9]+' "$1" | head -n1 | grep -oE '[0-9]+$' || true
}

for bench in "${benches[@]}"; do
  out="${RECORDS[$bench]:-}"
  if [ -z "$out" ]; then
    echo "unknown bench '$bench' (known: ${!RECORDS[*]})" >&2
    exit 1
  fi

  old_rate=""
  if [ "$check" = 1 ] && [ -f "$out" ] && ! grep -q '"not yet recorded' "$out"; then
    if [ "$(shape_of "$out")" = "$smoke" ]; then
      old_rate="$(best_rate "$out")"
    else
      echo "($out was recorded at a different smoke/full shape; check skipped)"
    fi
  fi

  if [ "$smoke" = 1 ]; then
    env_name="$(echo "$bench" | tr '[:lower:]' '[:upper:]')_BENCH_SMOKE"
    echo "== recording $out via 'cargo bench --bench $bench' ($env_name=1) =="
    env "$env_name=1" cargo bench --manifest-path rust/Cargo.toml --bench "$bench"
  else
    echo "== recording $out via 'cargo bench --bench $bench' =="
    cargo bench --manifest-path rust/Cargo.toml --bench "$bench"
  fi

  if grep -q '"not yet recorded' "$out"; then
    echo "error: $out still looks like a stub after the run" >&2
    exit 1
  fi
  echo "recorded: $out"

  if [ -n "$old_rate" ] && awk -v o="$old_rate" 'BEGIN { exit !(o > 0) }'; then
    new_rate="$(best_rate "$out")"
    tol="${RECORD_BENCH_CHECK_TOLERANCE:-0.6}"
    if awk -v n="$new_rate" -v o="$old_rate" -v t="$tol" \
        'BEGIN { exit !(n < o * (1 - t)) }'; then
      echo "error: $out regressed: best rate $new_rate/s < $old_rate/s * (1 - $tol)" >&2
      exit 1
    fi
    echo "check ok: $out best rate $new_rate/s vs committed $old_rate/s (tol $tol)"
  fi
done
