#!/usr/bin/env bash
# Record the benchmark JSON files committed at the repo root.
#
# Each BENCH_*.json starts life as a stub ("status": "not yet recorded");
# the corresponding bench binary overwrites it with measured rows. The
# benches write to the *current working directory*, so this script must
# run from the repo root (it cd's there itself).
#
# Usage:
#   scripts/record_bench.sh            # all recorded benches
#   scripts/record_bench.sh transport  # just one

set -euo pipefail
cd "$(dirname "$0")/.."

# bench name -> file it records
declare -A RECORDS=(
  [pipeline]=BENCH_ingest.json
  [rescale]=BENCH_rescale.json
  [recovery]=BENCH_recovery.json
  [transport]=BENCH_transport.json
  [serving]=BENCH_serving.json
)

benches=("$@")
if [ ${#benches[@]} -eq 0 ]; then
  benches=(pipeline rescale recovery transport serving)
fi

for bench in "${benches[@]}"; do
  out="${RECORDS[$bench]:-}"
  if [ -z "$out" ]; then
    echo "unknown bench '$bench' (known: ${!RECORDS[*]})" >&2
    exit 1
  fi
  echo "== recording $out via 'cargo bench --bench $bench' =="
  cargo bench --manifest-path rust/Cargo.toml --bench "$bench"
  if grep -q '"status"' "$out"; then
    echo "error: $out still looks like a stub after the run" >&2
    exit 1
  fi
  echo "recorded: $out"
done
