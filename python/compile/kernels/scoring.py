"""Layer-1 Pallas kernel: tiled user-vs-item scoring.

This is the compute hot spot of both DISGD recommendation (Algorithm 2's
``for each p in I: r_up = U_u . I_p^T``) and the prequential evaluator: a
``(B, K) x (M, K)^T`` matmul where ``M`` (the worker-local item-state size)
dominates.

TPU mapping (DESIGN.md §Hardware-Adaptation): the item matrix is streamed
HBM->VMEM in ``(BLOCK_M, K)`` tiles via ``BlockSpec`` while the small user
block stays resident in VMEM across the whole grid; the per-tile
``jnp.dot`` targets the MXU with float32 accumulation. On this image the
kernel is lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls), so TPU efficiency is *estimated* from the block geometry —
see ``vmem_bytes``/``mxu_utilization`` below and EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default item-tile height. 256 rows x K=16 lanes of f32 = 16 KiB per tile:
# deep enough to amortize the HBM->VMEM copy, small enough that double
# buffering two tiles plus the user block and output slab stays well under
# a TPU core's ~16 MiB VMEM for every artifact variant we ship.
DEFAULT_BLOCK_M = 256


def _scoring_kernel(u_ref, i_ref, o_ref):
    """One grid step: score the resident user block against one item tile.

    ``u_ref``: (B, K) user block (same block every step — revisited).
    ``i_ref``: (BLOCK_M, K) item tile for grid index m.
    ``o_ref``: (B, BLOCK_M) output slab for grid index m.
    """
    # MXU-shaped contraction; accumulate in f32 regardless of input dtype.
    o_ref[...] = jnp.dot(
        u_ref[...], i_ref[...].T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def scores(
    u_batch: jnp.ndarray,
    items: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas-tiled equivalent of ``ref.scores_ref``.

    Args:
      u_batch: ``(B, K)`` user vectors.
      items:   ``(M, K)`` item matrix; ``M`` must be a multiple of
               ``block_m`` (the Rust item store pads capacity to the
               artifact bucket, which is always a multiple of 256).
      block_m: item-tile height (HBM->VMEM streaming granularity).
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      ``(B, M)`` float32 scores.
    """
    b, k = u_batch.shape
    m, k2 = items.shape
    assert k == k2, f"latent dim mismatch: {k} vs {k2}"
    block_m = min(block_m, m)
    assert m % block_m == 0, f"M={m} not a multiple of block_m={block_m}"

    grid = (m // block_m,)
    return pl.pallas_call(
        _scoring_kernel,
        grid=grid,
        in_specs=[
            # User block: revisited every grid step, stays in VMEM.
            pl.BlockSpec((b, k), lambda mi: (0, 0)),
            # Item tile: streamed, one (block_m, K) slab per step.
            pl.BlockSpec((block_m, k), lambda mi: (mi, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_m), lambda mi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=interpret,
    )(u_batch, items)


def vmem_bytes(b: int, k: int, block_m: int = DEFAULT_BLOCK_M) -> int:
    """Estimated VMEM footprint (bytes) of one grid step, double-buffered.

    user block + 2x item tile (double buffering) + 2x output slab.
    Used by DESIGN.md §Perf to validate artifact block geometry.
    """
    f32 = 4
    return f32 * (b * k + 2 * block_m * k + 2 * b * block_m)


def mxu_utilization(b: int, k: int) -> float:
    """Estimated MXU lane utilization for one (B,K)x(K,BLOCK_M) pass.

    The 128x128 systolic array is fed a (B, K) LHS; lanes beyond B and
    sublanes beyond K idle. Utilization = (min(B,128)/128) * (min(K,128)/128).
    K=10..16 and B=1 are intrinsically low — the paper's workload is a
    skinny GEMV; batching (B=32) is the lever, see EXPERIMENTS.md §Perf.
    """
    return (min(b, 128) / 128.0) * (min(k, 128) / 128.0)
