"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the Pallas kernels (and the Rust native backend)
are validated against. They implement, with no tiling or fusion tricks:

* ``scores_ref``      — batched user-vs-item dot-product scoring
                        (the inner loop of Algorithm 2 / Equation 2).
* ``isgd_update_ref`` — one ISGD step per (user, item) pair
                        (Equations 3 and 4, sequential semantics: the item
                        update sees the already-updated user vector, exactly
                        as Algorithm 2 is written).
* ``topn_ref``        — masked top-N selection over scores.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def scores_ref(u_batch: jnp.ndarray, items: jnp.ndarray) -> jnp.ndarray:
    """Score every user vector against every item vector.

    Args:
      u_batch: ``(B, K)`` user latent vectors.
      items:   ``(M, K)`` item latent matrix.

    Returns:
      ``(B, M)`` scores ``u · i^T`` (Equation 2's prediction term).
    """
    return u_batch @ items.T


def isgd_update_ref(
    u: jnp.ndarray,
    i: jnp.ndarray,
    eta: float,
    lam: float,
):
    """One ISGD step for a batch of (user, item) vector pairs.

    Implements Algorithm 2's update block literally (positive-only feedback,
    boolean rating => target 1):

        err  = 1 - U_u . I_i^T                     (Equation 2, r = 1)
        U_u <- U_u + eta(err * I_i - lam * U_u)    (Equation 3)
        I_i <- I_i + eta(err * U_u - lam * I_i)    (Equation 4)

    The item update uses the *updated* ``U_u`` — the statements are
    sequential in Algorithm 2, and the Rust native backend matches this.

    Args:
      u:   ``(B, K)`` user vectors.
      i:   ``(B, K)`` item vectors (row b pairs with row b of ``u``).
      eta: learning rate.
      lam: L2 regularization.

    Returns:
      ``(u_new, i_new, err)`` with shapes ``(B, K), (B, K), (B,)``.
    """
    err = 1.0 - jnp.sum(u * i, axis=-1, keepdims=True)  # (B, 1)
    u_new = u + eta * (err * i - lam * u)
    i_new = i + eta * (err * u_new - lam * i)
    return u_new, i_new, err[:, 0]


def topn_ref(
    u_batch: jnp.ndarray,
    items: jnp.ndarray,
    valid: jnp.ndarray,
    n: int,
):
    """Masked top-N recommendation scores.

    Invalid item slots (``valid == 0``; capacity padding in the Rust
    runtime's item store) are pushed to -1e9 so they can never enter the
    top-N while keeping shapes static for AOT lowering.

    Args:
      u_batch: ``(B, K)`` user vectors.
      items:   ``(M, K)`` item matrix (rows past the live count are padding).
      valid:   ``(M,)`` float mask, 1.0 for live item rows, 0.0 for padding.
      n:       size of the recommendation list (compile-time constant).

    Returns:
      ``(values, indices)`` of shapes ``(B, n)`` each.
    """
    scores = scores_ref(u_batch, items)
    masked = scores + (valid - 1.0) * 1e9
    return jax.lax.top_k(masked, n)
