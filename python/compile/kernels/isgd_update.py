"""Layer-1 Pallas kernel: fused ISGD rank-1 update step.

Fuses Equation 2 (error), Equation 3 (user update) and Equation 4 (item
update) into a single VMEM-resident kernel over a batch of (user, item)
vector pairs. Keeping the three expressions in one kernel avoids writing
the ``err`` intermediate back to HBM and re-reading both vectors, which is
exactly the fusion XLA cannot guarantee across a jax.jit boundary when the
update is expressed as three separate ops fed from the Rust side.

Sequential semantics (item update sees the updated user vector) match
Algorithm 2 as written; the oracle is ``ref.isgd_update_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _isgd_kernel(u_ref, i_ref, hp_ref, u_out_ref, i_out_ref, err_out_ref):
    """Fused ISGD step for one (B, K) block of pairs.

    ``hp_ref`` is a (1, 2) block holding [eta, lam] so one artifact serves
    any hyper-parameter setting (the paper tunes eta/lam per dataset).
    """
    u = u_ref[...]
    i = i_ref[...]
    eta = hp_ref[0, 0]
    lam = hp_ref[0, 1]
    err = 1.0 - jnp.sum(u * i, axis=-1, keepdims=True)  # (B, 1)
    u_new = u + eta * (err * i - lam * u)
    # Sequential: the item update uses u_new (Algorithm 2 statement order).
    i_new = i + eta * (err * u_new - lam * i)
    u_out_ref[...] = u_new
    i_out_ref[...] = i_new
    err_out_ref[...] = err


@functools.partial(jax.jit, static_argnames=("interpret",))
def isgd_update(
    u: jnp.ndarray,
    i: jnp.ndarray,
    eta_lam: jnp.ndarray,
    *,
    interpret: bool = True,
):
    """Pallas-fused equivalent of ``ref.isgd_update_ref``.

    Args:
      u:       ``(B, K)`` user vectors.
      i:       ``(B, K)`` item vectors, row-paired with ``u``.
      eta_lam: ``(1, 2)`` float32 ``[[eta, lam]]``.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      ``(u_new, i_new, err)`` with shapes ``(B, K), (B, K), (B, 1)``.
    """
    b, k = u.shape
    assert i.shape == (b, k)
    assert eta_lam.shape == (1, 2)
    return pl.pallas_call(
        _isgd_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, k), lambda _: (0, 0)),
            pl.BlockSpec((b, k), lambda _: (0, 0)),
            pl.BlockSpec((1, 2), lambda _: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda _: (0, 0)),
            pl.BlockSpec((b, k), lambda _: (0, 0)),
            pl.BlockSpec((b, 1), lambda _: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=interpret,
    )(u, i, eta_lam)
