"""Layer-2 JAX model: the DISGD compute graph, built on the L1 kernels.

Two jitted entry points are AOT-lowered (see ``aot.py``) and executed from
the Rust coordinator via PJRT; Python never runs on the request path.

* ``recommend_topn`` — masked top-N scoring of a user batch against the
  worker-local item matrix (Algorithm 2's recommendation half, plus the
  capacity-padding mask the static-shape AOT contract requires).
* ``isgd_step``      — the fused ISGD model update (Algorithm 2's learning
  half; Equations 2-4).

Both call the Pallas kernels so the kernels lower into the same HLO
artifact the Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import isgd_update as isgd_kernel
from compile.kernels import scoring


@functools.partial(jax.jit, static_argnames=("n",))
def recommend_topn(
    u_batch: jnp.ndarray,
    items: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    n: int,
):
    """Top-N recommendation scores for a batch of users.

    Args:
      u_batch: ``(B, K)`` user latent vectors.
      items:   ``(M, K)`` item latent matrix, capacity-padded: rows at or
               beyond the live item count are arbitrary.
      valid:   ``(M,)`` float mask, 1.0 on live rows, 0.0 on padding.
      n:       recommendation-list length (static; the Rust side over-fetches
               ``n > N`` so already-rated items can be filtered locally).

    Returns:
      ``(values, indices)``, each ``(B, n)``; indices are row ids into the
      worker-local item store (the Rust side maps them back to item ids).
    """
    raw = scoring.scores(u_batch, items)
    # Push padding rows to -1e9: cheaper than a where() and exact enough —
    # live ISGD scores are O(1) in magnitude (vectors start ~N(0, 0.1)).
    masked = raw + (valid - 1.0)[None, :] * 1e9
    values, indices = _topk_via_sort(masked, n)
    return values, indices


def _topk_via_sort(scores: jnp.ndarray, n: int):
    """Top-k lowered through HLO `sort` instead of the `topk` op.

    jax.lax.top_k emits the modern `topk(..., largest=true)` HLO
    instruction, which the xla_extension 0.5.1 text parser (the version
    the Rust `xla` crate links) rejects. A descending key-value sort plus
    a static slice lowers to the classic `sort` + `slice` ops that
    round-trip cleanly (see DESIGN.md §3 and aot_recipe notes).
    """
    b, m = scores.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (b, m), dimension=1)
    # Ascending sort on negated scores == descending on scores; ties break
    # toward the lower index because the iota is carried as the value.
    neg_sorted, idx_sorted = jax.lax.sort_key_val(-scores, iota, dimension=1)
    return -neg_sorted[:, :n], idx_sorted[:, :n]


@jax.jit
def isgd_step(u: jnp.ndarray, i: jnp.ndarray, eta_lam: jnp.ndarray):
    """One fused ISGD update for a batch of (user, item) vector pairs.

    Thin L2 wrapper over the L1 fused kernel; exists so the AOT artifact
    boundary is a model-level function, not a kernel-level one.

    Args:
      u:       ``(B, K)`` user vectors.
      i:       ``(B, K)`` paired item vectors.
      eta_lam: ``(1, 2)`` ``[[eta, lam]]`` hyper-parameters.

    Returns:
      ``(u_new, i_new, err)`` — shapes ``(B, K), (B, K), (B, 1)``.
    """
    return isgd_kernel.isgd_update(u, i, eta_lam)


@functools.partial(jax.jit, static_argnames=("n",))
def recommend_and_update(
    u_batch: jnp.ndarray,
    items: jnp.ndarray,
    valid: jnp.ndarray,
    i_rated: jnp.ndarray,
    eta_lam: jnp.ndarray,
    *,
    n: int,
):
    """Fused prequential step: recommend first, then learn (Algorithm 4).

    The prequential evaluator always performs recommend-then-update for the
    same user; fusing them into one artifact halves the PJRT call count on
    the hot path (see EXPERIMENTS.md §Perf).

    Args:
      u_batch: ``(B, K)`` user vectors.
      items:   ``(M, K)`` capacity-padded item matrix.
      valid:   ``(M,)`` live-row mask.
      i_rated: ``(B, K)`` the item vector each user just rated (the training
               half updates against *this* item, not the recommended ones).
      eta_lam: ``(1, 2)`` ``[[eta, lam]]``.
      n:       over-fetched recommendation-list length.

    Returns:
      ``(values, indices, u_new, i_new, err)``.
    """
    values, indices = recommend_topn(u_batch, items, valid, n=n)
    u_new, i_new, err = isgd_step(u_batch, i_rated, eta_lam)
    return values, indices, u_new, i_new, err
