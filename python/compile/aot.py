"""AOT lowering: JAX model -> HLO *text* artifacts + manifest.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. HLO text — NOT ``lowered.compile().serialize()`` — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that xla_extension 0.5.1 rejects; the text parser reassigns ids.

Artifact variants form static-shape buckets (the stream grows the item
matrix at runtime, so the Rust item store capacity-pads to the next
bucket):

* ``topn_b{B}_m{M}``       — recommend_topn, (B,K)x(M,K) -> top-n.
* ``isgd_b{B}``            — fused ISGD update for B pairs.
* ``recupd_b{B}_m{M}``     — fused recommend-then-update (prequential hot
                             path; halves PJRT calls per event).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from ``python/``).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Paper hyper-parameters: k = 10 latent features (Section 5.3.1).
LATENT_K = 10
# Over-fetch factor for the top-N list: the evaluator needs N=10 *unrated*
# items; rust filters the user's history out of a longer static list.
TOPN_OVERFETCH = 50
# Item-store capacity buckets (multiples of the scoring kernel's BLOCK_M).
M_BUCKETS = (1024, 4096, 16384)
# User micro-batch sizes: 1 = per-event path, 32 = batched evaluator path.
B_SIZES = (1, 32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_desc(shapes):
    return [{"shape": list(s), "dtype": "f32"} for s in shapes]


def build_variants():
    """Yield (name, lowered, meta) for every artifact variant."""
    for b in B_SIZES:
        # Fused ISGD update: inputs u(B,K), i(B,K), eta_lam(1,2).
        name = f"isgd_b{b}"
        lowered = jax.jit(model.isgd_step).lower(
            _spec((b, LATENT_K)), _spec((b, LATENT_K)), _spec((1, 2))
        )
        yield name, lowered, {
            "kind": "isgd",
            "b": b,
            "k": LATENT_K,
            "inputs": _io_desc([(b, LATENT_K), (b, LATENT_K), (1, 2)]),
            "outputs": _io_desc([(b, LATENT_K), (b, LATENT_K), (b, 1)]),
        }
        for m in M_BUCKETS:
            # Masked top-n scoring.
            name = f"topn_b{b}_m{m}"
            fn = lambda u, items, valid: model.recommend_topn(
                u, items, valid, n=TOPN_OVERFETCH
            )
            lowered = jax.jit(fn).lower(
                _spec((b, LATENT_K)), _spec((m, LATENT_K)), _spec((m,))
            )
            yield name, lowered, {
                "kind": "topn",
                "b": b,
                "m": m,
                "k": LATENT_K,
                "n": TOPN_OVERFETCH,
                "inputs": _io_desc([(b, LATENT_K), (m, LATENT_K), (m,)]),
                "outputs": [
                    {"shape": [b, TOPN_OVERFETCH], "dtype": "f32"},
                    {"shape": [b, TOPN_OVERFETCH], "dtype": "s32"},
                ],
            }
            # Fused recommend-then-update (prequential hot path).
            name = f"recupd_b{b}_m{m}"
            fn2 = lambda u, items, valid, i_rated, eta_lam: (
                model.recommend_and_update(
                    u, items, valid, i_rated, eta_lam, n=TOPN_OVERFETCH
                )
            )
            lowered = jax.jit(fn2).lower(
                _spec((b, LATENT_K)),
                _spec((m, LATENT_K)),
                _spec((m,)),
                _spec((b, LATENT_K)),
                _spec((1, 2)),
            )
            yield name, lowered, {
                "kind": "recupd",
                "b": b,
                "m": m,
                "k": LATENT_K,
                "n": TOPN_OVERFETCH,
                "inputs": _io_desc(
                    [(b, LATENT_K), (m, LATENT_K), (m,), (b, LATENT_K), (1, 2)]
                ),
                "outputs": [
                    {"shape": [b, TOPN_OVERFETCH], "dtype": "f32"},
                    {"shape": [b, TOPN_OVERFETCH], "dtype": "s32"},
                    {"shape": [b, LATENT_K], "dtype": "f32"},
                    {"shape": [b, LATENT_K], "dtype": "f32"},
                    {"shape": [b, 1], "dtype": "f32"},
                ],
            }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="comma-separated variant-name filter"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {"latent_k": LATENT_K, "topn_overfetch": TOPN_OVERFETCH,
                "m_buckets": list(M_BUCKETS), "b_sizes": list(B_SIZES),
                "artifacts": []}
    for name, lowered, meta in build_variants():
        if only and name not in only:
            continue
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = {"name": name, "file": fname, **meta}
        manifest["artifacts"].append(entry)
        print(f"  wrote {fname:24s} ({len(text)//1024} KiB)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
