"""Layer-2 model tests: masked top-N semantics, fused prequential step,
and shape contracts the Rust runtime relies on."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _rand(shape, seed, scale=0.1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, size=shape), dtype=jnp.float32)


class TestRecommendTopn:
    def test_matches_ref(self):
        u = _rand((4, 10), seed=1)
        items = _rand((512, 10), seed=2)
        valid = jnp.ones((512,), dtype=jnp.float32)
        vals, idx = model.recommend_topn(u, items, valid, n=10)
        rvals, ridx = ref.topn_ref(u, items, valid, 10)
        np.testing.assert_allclose(vals, rvals, rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(idx, ridx)

    def test_padding_rows_never_recommended(self):
        m, live = 512, 40
        u = _rand((2, 10), seed=3)
        # Padding rows get huge raw scores; the mask must bury them anyway.
        items = jnp.asarray(
            np.vstack(
                [
                    np.random.default_rng(4).normal(0, 0.1, (live, 10)),
                    np.full((m - live, 10), 10.0),
                ]
            ),
            dtype=jnp.float32,
        )
        valid = jnp.asarray(
            np.concatenate([np.ones(live), np.zeros(m - live)]),
            dtype=jnp.float32,
        )
        _, idx = model.recommend_topn(u, items, valid, n=20)
        assert int(jnp.max(idx)) < live

    def test_topn_sorted_descending(self):
        u = _rand((1, 10), seed=5)
        items = _rand((256, 10), seed=6)
        valid = jnp.ones((256,), dtype=jnp.float32)
        vals, _ = model.recommend_topn(u, items, valid, n=15)
        v = np.asarray(vals[0])
        assert np.all(np.diff(v) <= 1e-7)

    def test_indices_are_i32(self):
        u = _rand((1, 10), seed=7)
        items = _rand((256, 10), seed=8)
        valid = jnp.ones((256,), dtype=jnp.float32)
        _, idx = model.recommend_topn(u, items, valid, n=5)
        assert idx.dtype == jnp.int32


class TestRecommendAndUpdate:
    def test_equals_unfused_pipeline(self):
        b, m, k, n = 2, 256, 10, 12
        u = _rand((b, k), seed=9)
        items = _rand((m, k), seed=10)
        valid = jnp.ones((m,), dtype=jnp.float32)
        i_rated = _rand((b, k), seed=11)
        eta_lam = jnp.asarray([[0.05, 0.01]], dtype=jnp.float32)

        vals, idx, u_new, i_new, err = model.recommend_and_update(
            u, items, valid, i_rated, eta_lam, n=n
        )
        vals2, idx2 = model.recommend_topn(u, items, valid, n=n)
        u2, i2, err2 = model.isgd_step(u, i_rated, eta_lam)
        np.testing.assert_allclose(vals, vals2, rtol=1e-6)
        np.testing.assert_array_equal(idx, idx2)
        np.testing.assert_allclose(u_new, u2, rtol=1e-6)
        np.testing.assert_allclose(i_new, i2, rtol=1e-6)
        np.testing.assert_allclose(err, err2, rtol=1e-6)

    def test_recommend_before_update(self):
        # Prequential protocol (Algorithm 4): the recommendation must be
        # computed from the PRE-update user vector.
        b, m, k = 1, 256, 10
        u = _rand((b, k), seed=12)
        items = _rand((m, k), seed=13)
        valid = jnp.ones((m,), dtype=jnp.float32)
        i_rated = items[3:4] * 5.0  # strong update signal
        eta_lam = jnp.asarray([[0.9, 0.0]], dtype=jnp.float32)
        vals, _, _, _, _ = model.recommend_and_update(
            u, items, valid, i_rated, eta_lam, n=5
        )
        pre_vals, _ = model.recommend_topn(u, items, valid, n=5)
        np.testing.assert_allclose(vals, pre_vals, rtol=1e-6)


class TestAotVariants:
    def test_manifest_variants_lower(self):
        """Every declared artifact variant must trace and lower to HLO text."""
        from compile import aot

        count = 0
        for name, lowered, meta in aot.build_variants():
            # Lowering already happened inside build_variants; converting the
            # biggest buckets to HLO text is covered by make artifacts. Here
            # we check the small buckets end-to-end.
            if meta.get("m", 1024) == 1024:
                text = aot.to_hlo_text(lowered)
                assert "ENTRY" in text
                count += 1
        assert count >= 4

    def test_hlo_text_parses_shapes(self):
        from compile import aot
        import jax

        spec = jax.ShapeDtypeStruct((1, 10), jnp.float32)
        lowered = jax.jit(model.isgd_step).lower(
            spec, spec, jax.ShapeDtypeStruct((1, 2), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        assert "f32[1,10]" in text
