"""Hypothesis sweeps: Pallas kernels vs pure-jnp oracle over randomized
shapes, seeds and hyper-parameters (the property-based half of the L1
correctness signal)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import isgd_update, ref, scoring

_SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, shape, scale):
    return jnp.asarray(rng.normal(0.0, scale, size=shape), dtype=jnp.float32)


@given(
    b=st.integers(min_value=1, max_value=48),
    m_blocks=st.integers(min_value=1, max_value=8),
    k=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([0.01, 0.1, 1.0]),
)
@settings(**_SETTINGS)
def test_scoring_matches_ref(b, m_blocks, k, seed, scale):
    rng = np.random.default_rng(seed)
    m = 128 * m_blocks
    u = _arr(rng, (b, k), scale)
    items = _arr(rng, (m, k), scale)
    got = scoring.scores(u, items, block_m=128)
    want = ref.scores_ref(u, items)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(
    b=st.integers(min_value=1, max_value=64),
    k=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    eta=st.floats(min_value=1e-4, max_value=0.5),
    lam=st.floats(min_value=0.0, max_value=0.2),
)
@settings(**_SETTINGS)
def test_isgd_update_matches_ref(b, k, seed, eta, lam):
    rng = np.random.default_rng(seed)
    u = _arr(rng, (b, k), 0.1)
    i = _arr(rng, (b, k), 0.1)
    eta_lam = jnp.asarray([[eta, lam]], dtype=jnp.float32)
    u_new, i_new, err = isgd_update.isgd_update(u, i, eta_lam)
    u_ref, i_ref, err_ref = ref.isgd_update_ref(u, i, eta, lam)
    np.testing.assert_allclose(u_new, u_ref, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(i_new, i_ref, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(err[:, 0], err_ref, rtol=1e-4, atol=1e-6)


@given(
    live=st.integers(min_value=1, max_value=255),
    n=st.integers(min_value=1, max_value=30),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(**_SETTINGS)
def test_topn_mask_excludes_padding(live, n, seed):
    from compile import model

    rng = np.random.default_rng(seed)
    m = 256
    u = _arr(rng, (1, 10), 0.1)
    items = _arr(rng, (m, 10), 0.1)
    valid = jnp.asarray(
        np.concatenate([np.ones(live), np.zeros(m - live)]), dtype=jnp.float32
    )
    _, idx = model.recommend_topn(u, items, valid, n=n)
    live_hits = np.asarray(idx[0])[: min(n, live)]
    assert np.all(live_hits < live)
