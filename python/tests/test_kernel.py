"""Kernel-vs-oracle correctness: the CORE numeric signal for L1.

Every Pallas kernel is compared against its pure-jnp oracle in ref.py with
assert_allclose over fixed representative shapes; the randomized/hypothesis
sweeps live in test_hypothesis.py.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import isgd_update, ref, scoring


def _rand(shape, seed, scale=0.1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, size=shape), dtype=jnp.float32)


class TestScoringKernel:
    @pytest.mark.parametrize("b", [1, 3, 32])
    @pytest.mark.parametrize("m", [256, 1024])
    @pytest.mark.parametrize("k", [10, 16])
    def test_matches_ref(self, b, m, k):
        u = _rand((b, k), seed=b * 100 + m + k)
        items = _rand((m, k), seed=b + m + k)
        got = scoring.scores(u, items)
        want = ref.scores_ref(u, items)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_block_not_dividing_m_rejected(self):
        u = _rand((1, 10), seed=0)
        items = _rand((300, 10), seed=1)  # 300 % 256 != 0
        with pytest.raises(AssertionError):
            scoring.scores(u, items)

    def test_small_m_clamps_block(self):
        # m < block_m must still work (block clamped to m).
        u = _rand((2, 10), seed=2)
        items = _rand((128, 10), seed=3)
        got = scoring.scores(u, items)
        np.testing.assert_allclose(got, ref.scores_ref(u, items), rtol=1e-5)

    def test_zero_user_vector_scores_zero(self):
        u = jnp.zeros((1, 10), dtype=jnp.float32)
        items = _rand((256, 10), seed=4)
        assert np.allclose(scoring.scores(u, items), 0.0)

    def test_vmem_budget_for_shipped_buckets(self):
        # Every shipped artifact bucket must fit comfortably in TPU VMEM.
        for b in (1, 32):
            for _m in (1024, 4096, 16384):
                assert scoring.vmem_bytes(b, 10) < 16 * 1024 * 1024

    def test_mxu_utilization_monotone_in_batch(self):
        assert scoring.mxu_utilization(32, 10) > scoring.mxu_utilization(1, 10)


class TestIsgdUpdateKernel:
    @pytest.mark.parametrize("b", [1, 7, 32])
    @pytest.mark.parametrize("k", [10, 16])
    def test_matches_ref(self, b, k):
        u = _rand((b, k), seed=b + k)
        i = _rand((b, k), seed=b * k + 1)
        eta, lam = 0.05, 0.01
        eta_lam = jnp.asarray([[eta, lam]], dtype=jnp.float32)
        u_new, i_new, err = isgd_update.isgd_update(u, i, eta_lam)
        u_ref, i_ref, err_ref = ref.isgd_update_ref(u, i, eta, lam)
        np.testing.assert_allclose(u_new, u_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(i_new, i_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(err[:, 0], err_ref, rtol=1e-5, atol=1e-6)

    def test_perfect_prediction_is_pure_decay(self):
        # err = 0 when u.i == 1: update reduces to weight decay.
        k = 10
        u = jnp.zeros((1, k), dtype=jnp.float32).at[0, 0].set(1.0)
        i = jnp.zeros((1, k), dtype=jnp.float32).at[0, 0].set(1.0)
        eta, lam = 0.05, 0.01
        eta_lam = jnp.asarray([[eta, lam]], dtype=jnp.float32)
        u_new, i_new, err = isgd_update.isgd_update(u, i, eta_lam)
        np.testing.assert_allclose(err, 0.0, atol=1e-6)
        np.testing.assert_allclose(u_new, u * (1 - eta * lam), rtol=1e-6)
        np.testing.assert_allclose(i_new, i * (1 - eta * lam), rtol=1e-6)

    def test_sequential_semantics(self):
        # The item update must see the UPDATED user vector (Algorithm 2
        # statement order), not the stale one.
        u = _rand((1, 10), seed=11)
        i = _rand((1, 10), seed=12)
        eta, lam = 0.5, 0.1  # large eta so the difference is visible
        eta_lam = jnp.asarray([[eta, lam]], dtype=jnp.float32)
        _, i_new, _ = isgd_update.isgd_update(u, i, eta_lam)
        err = 1.0 - jnp.sum(u * i)
        u_upd = u + eta * (err * i - lam * u)
        i_seq = i + eta * (err * u_upd - lam * i)      # sequential (correct)
        i_par = i + eta * (err * u - lam * i)          # parallel (wrong)
        np.testing.assert_allclose(i_new, i_seq, rtol=1e-5)
        assert not np.allclose(i_new, i_par, rtol=1e-5)

    def test_converges_toward_target(self):
        # Repeated updates on the same pair must drive err -> 0.
        u = _rand((1, 10), seed=21)
        i = _rand((1, 10), seed=22)
        eta_lam = jnp.asarray([[0.1, 0.001]], dtype=jnp.float32)
        errs = []
        for _ in range(200):
            u, i, err = isgd_update.isgd_update(u, i, eta_lam)
            errs.append(float(abs(err[0, 0])))
        assert errs[-1] < 0.05
        assert errs[-1] < errs[0]
